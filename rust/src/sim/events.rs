//! Event queue for the simulator: a min-heap on simulation time.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::coordinator::PoolRole;
use crate::{InstanceId, RequestId, Time};

/// Discrete simulation events.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A request enters the system (goes to a prefill queue).
    Arrival { request: RequestId },
    /// A prefill instance finishes its current request.
    PrefillDone {
        prefill: InstanceId,
        request: RequestId,
    },
    /// A decode instance completes one batched iteration.
    DecodeStep { instance: InstanceId, epoch: u64 },
    /// KV transfer for a migration completes. `kv_tokens` is the exact
    /// amount reserved on the destination at migration start (released on
    /// completion — carrying it avoids recomputing it from request state,
    /// which could drift from what was actually reserved).
    MigrationDone {
        request: RequestId,
        from: InstanceId,
        to: InstanceId,
        kv_tokens: u64,
    },
    /// Periodic scheduler tick (Algorithm 1 interval).
    SchedulerTick,
    /// A multi-round session's next turn arrives (scheduled at the prior
    /// turn's completion + think time; the request record is created when
    /// this fires). `turn` indexes the session script in the
    /// [`crate::workload::SessionPlan`].
    SessionFollowUp { session: u32, turn: u32 },
    /// Elastic-pool scale interval: sample the pool, run the scaling
    /// policy through the control loop, execute at most one action.
    ScaleTick,
    /// A provisioned or flipped instance finished its modeled warm-up and
    /// joins the pool in `role`.
    InstanceReady { role: PoolRole },
    /// A draining decode instance ran out of residents (batch, pending
    /// queue and inbound reservations all empty): retire it, or re-role
    /// it if the drain was started by a flip.
    DrainComplete { instance: InstanceId },
    /// A cached session prefix finished moving (or being recomputed) for
    /// a follow-up turn that was dispatched away from the instance holding
    /// it. The fire time is min(transfer, recompute) of the costmodel
    /// comparison; `tokens` is the prefix footprint reserved on `to`.
    PrefixTransferDone {
        request: RequestId,
        from: InstanceId,
        to: InstanceId,
        tokens: u64,
    },
    /// Fault injection: decode instance `instance` crashes. Its KV cache
    /// (batch residents, prefix cache) is lost; in-flight and pending
    /// requests re-queue through the recompute path. `down_s <= 0` means
    /// the crash is permanent (no recovery is scheduled).
    InstanceFailure { instance: InstanceId, down_s: f64 },
    /// A previously failed decode instance comes back, empty, as
    /// `Active` — the fault-injection counterpart of `InstanceReady`.
    InstanceRecovered { instance: InstanceId },
}

/// Explicit total-order tie-break key for events scheduled at the same
/// timestamp: `(class rank, primary id, secondary id)`. Before this key
/// existed, same-time ties were broken only by push order — fine inside
/// one queue, but nondeterministic the moment events are split across
/// shard queues and merged back (the merge would depend on the
/// partition). With the key, the pop order of any set of events is a
/// pure function of `(timestamp, key, global seq)` and therefore
/// invariant under sharding.
///
/// Class ranks follow the lifecycle: arrivals before prefill
/// completions before decode steps before migrations before control
/// ticks — so at a tied timestamp, work that *feeds* a decision is
/// applied before the decision fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct OrderKey {
    /// Event-class rank (variant order of the lifecycle, see
    /// [`Event::order_key`]).
    pub class: u8,
    /// Primary discriminator: request / instance / session id.
    pub a: u64,
    /// Secondary discriminator: instance / epoch / turn.
    pub b: u64,
}

impl Event {
    /// Variant name, as listed in the engine's `VALIDATED_EVENTS`
    /// coverage const (the invariant checker asserts membership before
    /// dispatching each event).
    pub fn name(&self) -> &'static str {
        match self {
            Event::Arrival { .. } => "Arrival",
            Event::PrefillDone { .. } => "PrefillDone",
            Event::DecodeStep { .. } => "DecodeStep",
            Event::MigrationDone { .. } => "MigrationDone",
            Event::SchedulerTick => "SchedulerTick",
            Event::SessionFollowUp { .. } => "SessionFollowUp",
            Event::ScaleTick => "ScaleTick",
            Event::InstanceReady { .. } => "InstanceReady",
            Event::DrainComplete { .. } => "DrainComplete",
            Event::PrefixTransferDone { .. } => "PrefixTransferDone",
            Event::InstanceFailure { .. } => "InstanceFailure",
            Event::InstanceRecovered { .. } => "InstanceRecovered",
        }
    }

    /// Total-order tie-break key for same-timestamp scheduling (see
    /// [`OrderKey`]). Every variant maps to a distinct class rank; the
    /// id fields make the key unique for any two events the engine can
    /// actually schedule at the same instant (two `DecodeStep`s for the
    /// same `(instance, epoch)` never coexist, etc.).
    pub fn order_key(&self) -> OrderKey {
        let (class, a, b) = match *self {
            Event::Arrival { request } => (0, request, 0),
            Event::PrefillDone { prefill, request } => (1, request, prefill as u64),
            Event::DecodeStep { instance, epoch } => (2, instance as u64, epoch),
            Event::MigrationDone { request, .. } => (3, request, 0),
            Event::SchedulerTick => (4, 0, 0),
            Event::SessionFollowUp { session, turn } => (5, session as u64, turn as u64),
            Event::ScaleTick => (6, 0, 0),
            Event::InstanceReady { role } => (7, role as u64, 0),
            Event::DrainComplete { instance } => (8, instance as u64, 0),
            Event::PrefixTransferDone { request, .. } => (9, request, 0),
            Event::InstanceFailure { instance, .. } => (10, instance as u64, 0),
            Event::InstanceRecovered { instance } => (11, instance as u64, 0),
        };
        OrderKey { class, a, b }
    }
}

#[derive(Clone, Debug)]
struct Scheduled {
    at: Time,
    key: OrderKey,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.key == other.key && self.seq == other.seq
    }
}
impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first. Ties are a
        // total order on (time, event key, seq): the explicit key makes
        // same-time ordering independent of which queue an event sits in
        // (required by the sharded merge); seq is the final push-order
        // tie-break for the pathological case of two identical keys.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.key.cmp(&self.key))
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic time-ordered event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: Time, event: Event) {
        self.seq += 1;
        let seq = self.seq;
        self.push_seq(at, seq, event);
    }

    /// Push with a caller-assigned sequence number. The sharded queue
    /// owns one *global* counter across all shard queues, so the final
    /// `(at, key, seq)` tie-break is identical no matter how events are
    /// partitioned; plain [`Self::push`] keeps a queue-local counter for
    /// standalone use.
    pub fn push_seq(&mut self, at: Time, seq: u64, event: Event) {
        debug_assert!(at.is_finite(), "event at non-finite time");
        self.heap.push(Scheduled {
            at,
            key: event.order_key(),
            seq,
            event,
        });
    }

    pub fn pop(&mut self) -> Option<(Time, Event)> {
        self.heap.pop().map(|s| (s.at, s.event))
    }

    /// Ordering triple of the head event without removing it — the
    /// sharded queue's merge tournament compares heads across shard
    /// queues with exactly the heap's own comparison key.
    pub fn peek_order(&self) -> Option<(Time, OrderKey, u64)> {
        self.heap.peek().map(|s| (s.at, s.key, s.seq))
    }

    #[allow(dead_code)]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    #[allow(dead_code)]
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::SchedulerTick);
        q.push(1.0, Event::Arrival { request: 1 });
        q.push(2.0, Event::Arrival { request: 2 });
        assert_eq!(q.pop().unwrap().0, 1.0);
        assert_eq!(q.pop().unwrap().0, 2.0);
        assert_eq!(q.pop().unwrap().0, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_pop_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::Arrival { request: 10 });
        q.push(1.0, Event::Arrival { request: 20 });
        match q.pop().unwrap().1 {
            Event::Arrival { request } => assert_eq!(request, 10),
            _ => panic!(),
        }
        match q.pop().unwrap().1 {
            Event::Arrival { request } => assert_eq!(request, 20),
            _ => panic!(),
        }
    }

    #[test]
    fn same_time_ties_pop_by_key_not_push_order() {
        // Push in reverse lifecycle order at one timestamp; the explicit
        // key must still pop arrivals before prefill completions before
        // decode steps before the tick.
        let mut q = EventQueue::new();
        q.push(2.0, Event::SchedulerTick);
        q.push(
            2.0,
            Event::DecodeStep {
                instance: 3,
                epoch: 9,
            },
        );
        q.push(
            2.0,
            Event::PrefillDone {
                prefill: 0,
                request: 7,
            },
        );
        q.push(2.0, Event::Arrival { request: 5 });
        let names: Vec<&str> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| e.name())
            .collect();
        assert_eq!(
            names,
            vec!["Arrival", "PrefillDone", "DecodeStep", "SchedulerTick"]
        );
    }

    #[test]
    fn order_keys_are_distinct_per_variant_and_sorted_by_id() {
        let a = Event::Arrival { request: 1 }.order_key();
        let b = Event::Arrival { request: 2 }.order_key();
        assert!(a < b);
        // Every variant gets its own class rank (names() coverage keeps
        // this list in sync with the enum).
        let classes = [
            Event::Arrival { request: 0 }.order_key().class,
            Event::PrefillDone {
                prefill: 0,
                request: 0,
            }
            .order_key()
            .class,
            Event::DecodeStep {
                instance: 0,
                epoch: 0,
            }
            .order_key()
            .class,
            Event::MigrationDone {
                request: 0,
                from: 0,
                to: 1,
                kv_tokens: 0,
            }
            .order_key()
            .class,
            Event::SchedulerTick.order_key().class,
            Event::SessionFollowUp {
                session: 0,
                turn: 0,
            }
            .order_key()
            .class,
            Event::ScaleTick.order_key().class,
            Event::InstanceReady {
                role: crate::coordinator::PoolRole::Decode,
            }
            .order_key()
            .class,
            Event::DrainComplete { instance: 0 }.order_key().class,
            Event::PrefixTransferDone {
                request: 0,
                from: 0,
                to: 1,
                tokens: 0,
            }
            .order_key()
            .class,
            Event::InstanceFailure {
                instance: 0,
                down_s: 1.0,
            }
            .order_key()
            .class,
            Event::InstanceRecovered { instance: 0 }.order_key().class,
        ];
        for (i, c) in classes.iter().enumerate() {
            assert_eq!(*c as usize, i, "class ranks must be dense and ordered");
        }
    }

    #[test]
    fn peek_order_matches_pop() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::SchedulerTick);
        q.push(1.0, Event::Arrival { request: 4 });
        let (at, key, _) = q.peek_order().unwrap();
        assert_eq!(at, 1.0);
        assert_eq!(key.class, 0);
        assert_eq!(q.pop().unwrap().0, 1.0);
    }
}
