//! Observability subsystem (`star trace`, DESIGN.md §16): request
//! lifecycle spans, a string-keyed metrics registry, and per-policy
//! decision attribution, wired identically through both drivers.
//!
//! Everything here is passive: the subsystem observes the run and never
//! feeds back into scheduling. Disabled (`[obs] enabled = false`, the
//! default) it is a strict no-op — the drivers' outputs are bit-for-bit
//! identical to a build without it, which `tests/obs_trace.rs` pins.
//! The sampling decision uses a dedicated PRNG stream off the run seed
//! ([`OBS_STREAM`]) so the retained span set is a pure function of
//! `(seed, request id, sample_rate)` — no wall clock, no iteration
//! order dependence (`star analyze` R1/R2 cover `obs/`).

pub mod attribution;
pub mod export;
pub mod registry;
pub mod spans;

pub use attribution::{AttributionLog, DecisionKind, DecisionRecord};
pub use export::{chrome_trace, jsonl};
pub use registry::{Histogram, MetricsRegistry, SeriesPoint};
pub use spans::{assemble, FlightRecorder, RequestSpan, SpanEvent, SpanKind};

use crate::metrics::TraceRow;
use crate::prng::Pcg64;

/// Dedicated PRNG stream id for span sampling ("OBSV"), following the
/// per-subsystem stream idiom (`sim::engine`'s FAULT_STREAM): obs draws
/// never perturb workload or fault streams, so enabling observability
/// cannot change a run's trajectory.
pub const OBS_STREAM: u64 = 0x4f42_5356;

/// Head-based sampling decision for one request: a pure function of
/// `(seed, request, rate)`, independent of when or how often it is
/// asked — the same request always gets the same verdict.
pub fn sample_request(seed: u64, request: crate::RequestId, rate: f64) -> bool {
    if rate >= 1.0 {
        return true;
    }
    if rate <= 0.0 {
        return false;
    }
    Pcg64::new(seed, OBS_STREAM).split(request).next_f64() < rate
}

/// One run's observability output, carried in `SimReport` /
/// `ServeOutcome`. Default (all-empty, `enabled == false`) for
/// obs-disabled runs.
#[derive(Clone, Debug, Default)]
pub struct ObsReport {
    pub enabled: bool,
    /// Sampled request-lifecycle spans (the flight recorder).
    pub spans: FlightRecorder,
    /// Counters / gauges / histograms + the per-tick time series.
    pub registry: MetricsRegistry,
    /// Per-policy decision attribution log.
    pub decisions: AttributionLog,
}

impl ObsReport {
    /// Multi-line human summary (the `star trace summarize` view).
    pub fn summary(&self) -> String {
        if !self.enabled {
            return "obs: disabled ([obs] enabled = false)".to_string();
        }
        let mut out = format!(
            "obs: spans {} retained ({} sampled of {} seen, {} dropped by ring)",
            self.spans.len(),
            self.spans.sampled,
            self.spans.seen,
            self.spans.dropped
        );
        out.push_str(&format!(
            "\nobs: registry {} counters, {} gauges, {} histograms, {} series points",
            self.registry.counters().count(),
            self.registry.gauges().count(),
            self.registry.histograms().count(),
            self.registry.series().len()
        ));
        for (k, v) in self.registry.counters() {
            out.push_str(&format!("\n  counter {k:<28} {v}"));
        }
        for (k, h) in self.registry.histograms() {
            out.push_str(&format!(
                "\n  hist    {k:<28} n {} mean {:.4} min {:.4} max {:.4}",
                h.count,
                h.mean(),
                h.min,
                h.max
            ));
        }
        if self.decisions.is_empty() {
            out.push_str("\nobs: no decisions recorded");
        } else {
            out.push_str(&format!(
                "\nobs: {} decision records\n{}",
                self.decisions.len(),
                self.decisions.summary()
            ));
        }
        out
    }
}

/// Assemble the final report from the raw run artifacts. Pure
/// post-processing at report time; with `enabled == false` the inputs
/// are empty and the output is `ObsReport::default()`-shaped.
pub fn assemble_report(
    enabled: bool,
    seed: u64,
    sample_rate: f64,
    ring_capacity: usize,
    rows: &[TraceRow],
    registry: MetricsRegistry,
    decisions: AttributionLog,
) -> ObsReport {
    if !enabled {
        return ObsReport::default();
    }
    let spans = spans::assemble(rows, &decisions, seed, sample_rate, ring_capacity);
    ObsReport {
        enabled,
        spans,
        registry,
        decisions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::TraceEvent;

    #[test]
    fn sample_request_is_pure_and_rate_bounded() {
        for id in 0..50u64 {
            assert_eq!(sample_request(3, id, 0.5), sample_request(3, id, 0.5));
            assert!(sample_request(3, id, 1.0));
            assert!(!sample_request(3, id, 0.0));
        }
        let kept = (0..1000u64).filter(|&id| sample_request(11, id, 0.3)).count();
        assert!((200..400).contains(&kept), "rate 0.3 kept {kept}/1000");
    }

    #[test]
    fn disabled_assembly_is_default_shaped() {
        let rows = vec![TraceRow { t: 0.0, event: TraceEvent::Arrived { request: 1 } }];
        let obs = assemble_report(
            false,
            0,
            1.0,
            16,
            &rows,
            MetricsRegistry::new(false),
            AttributionLog::new(false),
        );
        assert!(!obs.enabled);
        assert!(obs.spans.is_empty());
        assert_eq!(obs.spans.seen, 0);
        assert!(obs.decisions.is_empty());
        assert!(obs.summary().contains("disabled"));
    }

    #[test]
    fn enabled_summary_lists_spans_and_decisions() {
        let rows = vec![
            TraceRow { t: 0.0, event: TraceEvent::Arrived { request: 1 } },
            TraceRow { t: 1.0, event: TraceEvent::Finished { request: 1, instance: 0 } },
        ];
        let mut log = AttributionLog::new(true);
        log.record_dispatch("current_load", 1, 2, 0);
        let mut reg = MetricsRegistry::new(true);
        reg.inc("requests.arrived", 1);
        let obs = assemble_report(true, 5, 1.0, 16, &rows, reg, log);
        let s = obs.summary();
        assert!(s.contains("spans 1 retained"), "{s}");
        assert!(s.contains("requests.arrived"), "{s}");
        assert!(s.contains("current_load"), "{s}");
    }
}
