//! Decision attribution: which policy decided what, when, at what cost.
//!
//! The [`crate::coordinator::ControlLoop`] records one
//! [`DecisionRecord`] per dispatch call, per reschedule interval (plus
//! one per decided migration, carrying the request id so per-request
//! joins work), per scale interval, and per prefix-cache consult. Cost
//! is a deterministic work proxy in the simulator (candidates scanned,
//! decisions per tick); the live server layers wall-clock µs on top via
//! [`AttributionLog::note_last_cost_us`] — serve is the R2-exempt layer,
//! this module itself never reads a clock.

use std::collections::BTreeMap;

use crate::{InstanceId, RequestId, Time};

/// Which control-loop surface produced a record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DecisionKind {
    Dispatch,
    Reschedule,
    Scale,
    Cache,
}

impl DecisionKind {
    pub fn name(&self) -> &'static str {
        match self {
            DecisionKind::Dispatch => "dispatch",
            DecisionKind::Reschedule => "reschedule",
            DecisionKind::Scale => "scale",
            DecisionKind::Cache => "cache",
        }
    }
}

/// One attributed decision.
#[derive(Clone, Debug)]
pub struct DecisionRecord {
    /// Driver time of the decision (sim clock or serve run clock).
    pub t: Time,
    pub kind: DecisionKind,
    /// Registry name of the policy that decided.
    pub policy: String,
    /// The request the decision touched, when one is attributable
    /// (dispatch, per-migration reschedule, cache consults).
    pub request: Option<RequestId>,
    /// Work proxy: candidates scanned to reach the decision.
    pub candidates: u64,
    /// Actions taken (migrations decided, scale actions admitted,
    /// cache hit = 1 / miss = 0; dispatch always 1).
    pub actions: u64,
    /// Chosen instance, when the decision places work somewhere.
    pub chosen: Option<InstanceId>,
    /// Measured decision cost in µs; 0 in the simulator (the work proxy
    /// above is the deterministic stand-in).
    pub cost_us: u64,
}

/// Append-only log of attributed decisions. All record methods are
/// no-ops while disabled, so the default-off path allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct AttributionLog {
    enabled: bool,
    now: Time,
    records: Vec<DecisionRecord>,
}

impl AttributionLog {
    pub fn new(enabled: bool) -> Self {
        AttributionLog {
            enabled,
            ..Default::default()
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Drivers stamp the decision clock before invoking the control
    /// loop; every record until the next call carries this time.
    #[inline]
    pub fn set_now(&mut self, t: Time) {
        self.now = t;
    }

    fn push(&mut self, mut rec: DecisionRecord) {
        rec.t = self.now;
        self.records.push(rec);
    }

    pub fn record_dispatch(
        &mut self,
        policy: &str,
        request: RequestId,
        candidates: u64,
        chosen: InstanceId,
    ) {
        if !self.enabled {
            return;
        }
        self.push(DecisionRecord {
            t: 0.0,
            kind: DecisionKind::Dispatch,
            policy: policy.to_string(),
            request: Some(request),
            candidates,
            actions: 1,
            chosen: Some(chosen),
            cost_us: 0,
        });
    }

    /// One record per reschedule interval: candidates scanned and
    /// migrations decided this tick.
    pub fn record_reschedule_tick(&mut self, policy: &str, candidates: u64, actions: u64) {
        if !self.enabled {
            return;
        }
        self.push(DecisionRecord {
            t: 0.0,
            kind: DecisionKind::Reschedule,
            policy: policy.to_string(),
            request: None,
            candidates,
            actions,
            chosen: None,
            cost_us: 0,
        });
    }

    /// One record per decided migration, carrying the request id.
    pub fn record_migration(&mut self, policy: &str, request: RequestId, dst: InstanceId) {
        if !self.enabled {
            return;
        }
        self.push(DecisionRecord {
            t: 0.0,
            kind: DecisionKind::Reschedule,
            policy: policy.to_string(),
            request: Some(request),
            candidates: 0,
            actions: 1,
            chosen: Some(dst),
            cost_us: 0,
        });
    }

    pub fn record_scale(&mut self, policy: &str, candidates: u64, actions: u64) {
        if !self.enabled {
            return;
        }
        self.push(DecisionRecord {
            t: 0.0,
            kind: DecisionKind::Scale,
            policy: policy.to_string(),
            request: None,
            candidates,
            actions,
            chosen: None,
            cost_us: 0,
        });
    }

    pub fn record_cache(&mut self, policy: &str, request: RequestId, hit: bool) {
        if !self.enabled {
            return;
        }
        self.push(DecisionRecord {
            t: 0.0,
            kind: DecisionKind::Cache,
            policy: policy.to_string(),
            request: Some(request),
            candidates: 0,
            actions: hit as u64,
            chosen: None,
            cost_us: 0,
        });
    }

    /// Attach a measured cost to the most recent record — the live
    /// server calls this right after timing a control-loop call.
    pub fn note_last_cost_us(&mut self, us: u64) {
        if let Some(last) = self.records.last_mut() {
            last.cost_us += us;
        }
    }

    pub fn records(&self) -> &[DecisionRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Every decision that touched `request`, in decision order.
    pub fn for_request(&self, request: RequestId) -> Vec<&DecisionRecord> {
        self.records
            .iter()
            .filter(|r| r.request == Some(request))
            .collect()
    }

    /// Per (kind, policy) aggregate: decisions, candidates scanned,
    /// actions taken, total measured µs — one line each, sorted.
    pub fn summary(&self) -> String {
        let mut agg: BTreeMap<(DecisionKind, &str), (u64, u64, u64, u64)> = BTreeMap::new();
        for r in &self.records {
            let e = agg.entry((r.kind, r.policy.as_str())).or_insert((0, 0, 0, 0));
            e.0 += 1;
            e.1 += r.candidates;
            e.2 += r.actions;
            e.3 += r.cost_us;
        }
        let mut out = String::new();
        for ((kind, policy), (n, cand, act, us)) in agg {
            if !out.is_empty() {
                out.push('\n');
            }
            out.push_str(&format!(
                "{:<10} {:<16} decisions {:>7} | candidates {:>9} | actions {:>6} | cost {} us",
                kind.name(),
                policy,
                n,
                cand,
                act,
                us
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_log_records_nothing() {
        let mut log = AttributionLog::new(false);
        log.set_now(1.0);
        log.record_dispatch("current_load", 7, 4, 2);
        log.record_reschedule_tick("star", 12, 1);
        log.record_scale("static", 4, 0);
        log.record_cache("lru", 7, true);
        assert!(log.is_empty());
    }

    #[test]
    fn records_carry_time_and_join_by_request() {
        let mut log = AttributionLog::new(true);
        log.set_now(2.5);
        log.record_dispatch("current_load", 7, 4, 2);
        log.set_now(3.0);
        log.record_reschedule_tick("star", 12, 1);
        log.record_migration("star", 7, 1);
        log.record_cache("lru", 9, false);
        assert_eq!(log.len(), 4);
        assert!((log.records()[0].t - 2.5).abs() < 1e-12);
        assert!((log.records()[1].t - 3.0).abs() < 1e-12);
        let touched = log.for_request(7);
        assert_eq!(touched.len(), 2);
        assert_eq!(touched[0].kind, DecisionKind::Dispatch);
        assert_eq!(touched[1].kind, DecisionKind::Reschedule);
        assert_eq!(touched[1].chosen, Some(1));
        assert_eq!(log.for_request(9)[0].actions, 0, "cache miss");
    }

    #[test]
    fn cost_notes_attach_to_the_last_record() {
        let mut log = AttributionLog::new(true);
        log.record_dispatch("slo_aware", 1, 8, 0);
        log.note_last_cost_us(42);
        log.note_last_cost_us(8);
        assert_eq!(log.records()[0].cost_us, 50);
    }

    #[test]
    fn summary_aggregates_per_kind_and_policy() {
        let mut log = AttributionLog::new(true);
        log.record_dispatch("current_load", 1, 4, 0);
        log.record_dispatch("current_load", 2, 4, 1);
        log.record_reschedule_tick("star", 20, 2);
        let s = log.summary();
        assert!(s.contains("dispatch"), "{s}");
        assert!(s.contains("current_load"), "{s}");
        assert!(s.contains("decisions       2"), "{s}");
        assert!(s.contains("star"), "{s}");
    }
}
