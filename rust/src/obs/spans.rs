//! Request-lifecycle span assembly: reconstructs a per-request timeline
//! (arrived → dispatched@instance → prefill → decode → finished, with
//! migrations, OOM recomputes and cache consults as span events) from
//! the flat [`crate::metrics::TraceRecorder`] rows plus the decision
//! log, into a bounded flight-recorder ring.
//!
//! Sampling is head-based and deterministic: whether a request is
//! retained is decided at its `Arrived` row from a dedicated PRNG
//! stream off the run seed ([`super::OBS_STREAM`]) — same seed ⇒
//! identical retained set, independent of event interleaving. No wall
//! clock, no hash-ordered collections (`star analyze` R1/R2 cover this
//! module).
//!
//! Analyze rule R6 (`trace-event-coverage`) checks this file: every
//! [`TraceEvent`] variant must appear in the assembler's match below,
//! so a newly added trace event cannot silently vanish from spans.

use std::collections::{BTreeMap, BTreeSet};

use super::attribution::{AttributionLog, DecisionKind};
use super::sample_request;
use crate::metrics::{TraceEvent, TraceRow};
use crate::{InstanceId, RequestId, Time};

/// One event on a request's timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanEvent {
    pub t: Time,
    pub kind: SpanKind,
}

/// What happened to the request at that instant.
#[derive(Clone, Debug, PartialEq)]
pub enum SpanKind {
    /// Placed onto a decode instance by the dispatch policy.
    Dispatched { instance: InstanceId },
    /// Prefill completed (KV ready for transfer to decode).
    PrefillDone { instance: InstanceId },
    /// Migrated between decode instances by the rescheduler.
    Migrated {
        src: InstanceId,
        dst: InstanceId,
        kv_tokens: u64,
    },
    /// Evicted by an OOM and re-queued for KV recompute.
    RecomputeQueued,
    /// Prefix-cache consult on a session follow-up turn.
    CacheConsult { hit: bool },
    /// Decode finished.
    Finished { instance: InstanceId },
}

impl SpanKind {
    /// Short label for summaries and exporters.
    pub fn label(&self) -> &'static str {
        match self {
            SpanKind::Dispatched { .. } => "dispatched",
            SpanKind::PrefillDone { .. } => "prefill_done",
            SpanKind::Migrated { .. } => "migrated",
            SpanKind::RecomputeQueued => "recompute_queued",
            SpanKind::CacheConsult { .. } => "cache_consult",
            SpanKind::Finished { .. } => "finished",
        }
    }
}

/// The reconstructed lifecycle of one sampled request.
#[derive(Clone, Debug)]
pub struct RequestSpan {
    pub request: RequestId,
    pub arrived: Time,
    /// `(t, instance)` of prefill completion, if reached.
    pub prefill_done: Option<(Time, InstanceId)>,
    /// `(t, instance)` of decode completion, if reached.
    pub finished: Option<(Time, InstanceId)>,
    /// Everything that happened in between, in time order.
    pub events: Vec<SpanEvent>,
}

impl RequestSpan {
    fn new(request: RequestId, arrived: Time) -> Self {
        RequestSpan {
            request,
            arrived,
            prefill_done: None,
            finished: None,
            events: Vec::new(),
        }
    }

    pub fn migrations(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, SpanKind::Migrated { .. }))
            .count()
    }

    /// Multi-line human-readable timeline (the `star trace
    /// slo-violations` view).
    pub fn timeline(&self) -> String {
        let mut out = format!("  {:>10.3}s  arrived", self.arrived);
        for e in &self.events {
            out.push('\n');
            let detail = match &e.kind {
                SpanKind::Dispatched { instance } => format!("dispatched -> instance {instance}"),
                SpanKind::PrefillDone { instance } => {
                    format!("prefill done @ instance {instance}")
                }
                SpanKind::Migrated { src, dst, kv_tokens } => {
                    format!("migrated {src} -> {dst} ({kv_tokens} KV tokens)")
                }
                SpanKind::RecomputeQueued => "OOM victim: re-queued for recompute".to_string(),
                SpanKind::CacheConsult { hit } => {
                    format!("prefix-cache consult: {}", if *hit { "hit" } else { "miss" })
                }
                SpanKind::Finished { instance } => format!("finished @ instance {instance}"),
            };
            out.push_str(&format!("  {:>10.3}s  {detail}", e.t));
        }
        out
    }
}

/// Bounded ring of sampled request spans — the flight recorder. Spans
/// are kept in first-arrival order; once `capacity` is exceeded the
/// oldest are dropped (and counted), like any flight recorder.
#[derive(Clone, Debug, Default)]
pub struct FlightRecorder {
    spans: Vec<RequestSpan>,
    /// Requests the sampler retained (before the ring bound).
    pub sampled: u64,
    /// Retained spans evicted by the ring bound.
    pub dropped: u64,
    /// Distinct requests observed arriving (sampled or not).
    pub seen: u64,
}

impl FlightRecorder {
    pub fn spans(&self) -> &[RequestSpan] {
        &self.spans
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    pub fn span_of(&self, request: RequestId) -> Option<&RequestSpan> {
        self.spans.iter().find(|s| s.request == request)
    }
}

/// Assemble the flight recorder from the flat trace plus the decision
/// log. Pure post-processing: runs once at report time, reads nothing
/// but its arguments, and is deterministic in them.
pub fn assemble(
    rows: &[TraceRow],
    decisions: &AttributionLog,
    seed: u64,
    sample_rate: f64,
    capacity: usize,
) -> FlightRecorder {
    let mut spans: Vec<RequestSpan> = Vec::new();
    let mut index: BTreeMap<RequestId, usize> = BTreeMap::new();
    let mut seen: BTreeSet<RequestId> = BTreeSet::new();
    for row in rows {
        match &row.event {
            TraceEvent::Arrived { request } => {
                seen.insert(*request);
                if !index.contains_key(request) && sample_request(seed, *request, sample_rate) {
                    index.insert(*request, spans.len());
                    spans.push(RequestSpan::new(*request, row.t));
                }
            }
            TraceEvent::PrefillDone { request, instance } => {
                if let Some(&i) = index.get(request) {
                    spans[i].prefill_done = Some((row.t, *instance));
                    spans[i].events.push(SpanEvent {
                        t: row.t,
                        kind: SpanKind::PrefillDone { instance: *instance },
                    });
                }
            }
            TraceEvent::Finished { request, instance } => {
                if let Some(&i) = index.get(request) {
                    spans[i].finished = Some((row.t, *instance));
                    spans[i].events.push(SpanEvent {
                        t: row.t,
                        kind: SpanKind::Finished { instance: *instance },
                    });
                }
            }
            TraceEvent::Migration { request, src, dst, kv_tokens } => {
                if let Some(&i) = index.get(request) {
                    spans[i].events.push(SpanEvent {
                        t: row.t,
                        kind: SpanKind::Migrated {
                            src: *src,
                            dst: *dst,
                            kv_tokens: *kv_tokens,
                        },
                    });
                }
            }
            TraceEvent::RecomputeQueued { request } => {
                if let Some(&i) = index.get(request) {
                    spans[i].events.push(SpanEvent {
                        t: row.t,
                        kind: SpanKind::RecomputeQueued,
                    });
                }
            }
            TraceEvent::Oom { .. } => {
                // instance-level: each victim announces itself through
                // its own RecomputeQueued row, so there is nothing to
                // attach to a single request here
            }
            TraceEvent::KvSample { .. } => {
                // instance-level utilization sample; the registry's
                // time series carries this signal, not request spans
            }
        }
    }
    // The queued→dispatched edge lives in the decision log (the trace
    // has no dispatch row): merge dispatch + cache decisions in.
    for rec in decisions.records() {
        let Some(request) = rec.request else {
            continue;
        };
        let Some(&i) = index.get(&request) else {
            continue;
        };
        match rec.kind {
            DecisionKind::Dispatch => {
                if let Some(instance) = rec.chosen {
                    spans[i].events.push(SpanEvent {
                        t: rec.t,
                        kind: SpanKind::Dispatched { instance },
                    });
                }
            }
            DecisionKind::Cache => {
                spans[i].events.push(SpanEvent {
                    t: rec.t,
                    kind: SpanKind::CacheConsult {
                        hit: rec.actions > 0,
                    },
                });
            }
            DecisionKind::Reschedule | DecisionKind::Scale => {}
        }
    }
    for s in &mut spans {
        s.events
            .sort_by(|a, b| a.t.partial_cmp(&b.t).expect("finite span times"));
    }
    let sampled = spans.len() as u64;
    let mut dropped = 0u64;
    if spans.len() > capacity {
        dropped = (spans.len() - capacity) as u64;
        let overflow = spans.len() - capacity;
        spans.drain(..overflow);
    }
    FlightRecorder {
        spans,
        sampled,
        dropped,
        seen: seen.len() as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows() -> Vec<TraceRow> {
        vec![
            TraceRow { t: 0.0, event: TraceEvent::Arrived { request: 1 } },
            TraceRow { t: 0.1, event: TraceEvent::Arrived { request: 2 } },
            TraceRow { t: 0.5, event: TraceEvent::PrefillDone { request: 1, instance: 0 } },
            TraceRow {
                t: 1.0,
                event: TraceEvent::KvSample { instance: 0, kv_frac: 0.5, tokens: 10, batch: 1 },
            },
            TraceRow {
                t: 1.5,
                event: TraceEvent::Migration { request: 1, src: 0, dst: 2, kv_tokens: 64 },
            },
            TraceRow { t: 2.0, event: TraceEvent::Oom { instance: 2, victims: 1 } },
            TraceRow { t: 2.0, event: TraceEvent::RecomputeQueued { request: 1 } },
            TraceRow { t: 3.0, event: TraceEvent::Finished { request: 1, instance: 2 } },
        ]
    }

    #[test]
    fn assembles_full_lifecycle_in_time_order() {
        let mut log = AttributionLog::new(true);
        log.set_now(0.5);
        log.record_dispatch("current_load", 1, 3, 0);
        let fr = assemble(&rows(), &log, 42, 1.0, 1024);
        assert_eq!(fr.seen, 2);
        assert_eq!(fr.sampled, 2);
        assert_eq!(fr.dropped, 0);
        let s = fr.span_of(1).expect("request 1 sampled at rate 1.0");
        assert!((s.arrived - 0.0).abs() < 1e-12);
        assert_eq!(s.prefill_done, Some((0.5, 0)));
        assert_eq!(s.finished, Some((3.0, 2)));
        assert_eq!(s.migrations(), 1);
        let labels: Vec<&str> = s.events.iter().map(|e| e.kind.label()).collect();
        assert_eq!(
            labels,
            vec!["prefill_done", "dispatched", "migrated", "recompute_queued", "finished"]
        );
        let tl = s.timeline();
        assert!(tl.contains("arrived"), "{tl}");
        assert!(tl.contains("migrated 0 -> 2"), "{tl}");
        assert!(tl.contains("re-queued for recompute"), "{tl}");
    }

    #[test]
    fn sampling_is_deterministic_and_head_based() {
        let log = AttributionLog::new(false);
        let mut many = Vec::new();
        for id in 0..200u64 {
            many.push(TraceRow {
                t: id as f64,
                event: TraceEvent::Arrived { request: id },
            });
        }
        let a = assemble(&many, &log, 7, 0.5, 4096);
        let b = assemble(&many, &log, 7, 0.5, 4096);
        let ids = |fr: &FlightRecorder| -> Vec<RequestId> {
            fr.spans().iter().map(|s| s.request).collect()
        };
        assert_eq!(ids(&a), ids(&b), "same seed, same retained set");
        assert!(a.sampled > 20 && a.sampled < 180, "rate 0.5 keeps some, drops some");
        let c = assemble(&many, &log, 8, 0.5, 4096);
        assert_ne!(ids(&a), ids(&c), "different seed, different retained set");
    }

    #[test]
    fn ring_bound_drops_oldest_and_counts() {
        let log = AttributionLog::new(false);
        let mut many = Vec::new();
        for id in 0..50u64 {
            many.push(TraceRow {
                t: id as f64,
                event: TraceEvent::Arrived { request: id },
            });
        }
        let fr = assemble(&many, &log, 3, 1.0, 8);
        assert_eq!(fr.len(), 8);
        assert_eq!(fr.sampled, 50);
        assert_eq!(fr.dropped, 42);
        assert_eq!(fr.spans()[0].request, 42, "oldest dropped, newest kept");
    }
}
