//! Trace exporters: Chrome-trace-format JSON (loadable in Perfetto /
//! `chrome://tracing`) and a line-per-record JSONL dump.
//!
//! Both are hand-built deterministic string assemblies — fixed key
//! order, integer-µs timestamps, inputs iterated in their stored
//! (deterministic) order — so same-seed runs export byte-identical
//! bytes, which `tests/obs_trace.rs` pins.

use super::spans::{RequestSpan, SpanEvent, SpanKind};
use super::ObsReport;
use crate::Time;

/// Seconds → integer microseconds (Chrome trace `ts`/`dur` unit).
fn us(t: Time) -> i64 {
    (t * 1e6).round() as i64
}

/// Minimal JSON string escape (quotes, backslashes, control chars).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// `{"t":…,"kind":"…",…}` for one span event — shared by both formats.
fn span_event_json(e: &SpanEvent) -> String {
    let mut s = format!("{{\"t\":{:.6},\"kind\":\"{}\"", e.t, e.kind.label());
    match &e.kind {
        SpanKind::Dispatched { instance } | SpanKind::PrefillDone { instance } => {
            s.push_str(&format!(",\"instance\":{instance}"));
        }
        SpanKind::Migrated { src, dst, kv_tokens } => {
            s.push_str(&format!(",\"src\":{src},\"dst\":{dst},\"kv_tokens\":{kv_tokens}"));
        }
        SpanKind::RecomputeQueued => {}
        SpanKind::CacheConsult { hit } => {
            s.push_str(&format!(",\"hit\":{hit}"));
        }
        SpanKind::Finished { instance } => {
            s.push_str(&format!(",\"instance\":{instance}"));
        }
    }
    s.push('}');
    s
}

fn push_event(out: &mut Vec<String>, ev: String) {
    out.push(ev);
}

fn span_slices(out: &mut Vec<String>, s: &RequestSpan) {
    if let Some((pd, inst)) = s.prefill_done {
        push_event(
            out,
            format!(
                "{{\"name\":\"prefill\",\"cat\":\"request\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":0,\"tid\":{},\"args\":{{\"instance\":{}}}}}",
                us(s.arrived),
                us(pd) - us(s.arrived),
                s.request,
                inst
            ),
        );
        if let Some((fin, dinst)) = s.finished {
            push_event(
                out,
                format!(
                    "{{\"name\":\"decode\",\"cat\":\"request\",\"ph\":\"X\",\"ts\":{},\
                     \"dur\":{},\"pid\":0,\"tid\":{},\"args\":{{\"instance\":{}}}}}",
                    us(pd),
                    us(fin) - us(pd),
                    s.request,
                    dinst
                ),
            );
        }
    } else if let Some((fin, dinst)) = s.finished {
        // no prefill marker survived (e.g. trace started mid-flight):
        // still show the request's full extent
        push_event(
            out,
            format!(
                "{{\"name\":\"request\",\"cat\":\"request\",\"ph\":\"X\",\"ts\":{},\
                 \"dur\":{},\"pid\":0,\"tid\":{},\"args\":{{\"instance\":{}}}}}",
                us(s.arrived),
                us(fin) - us(s.arrived),
                s.request,
                dinst
            ),
        );
    }
    for e in &s.events {
        if matches!(e.kind, SpanKind::PrefillDone { .. } | SpanKind::Finished { .. }) {
            continue; // already the slice boundaries above
        }
        push_event(
            out,
            format!(
                "{{\"name\":\"{}\",\"cat\":\"request\",\"ph\":\"i\",\"ts\":{},\"pid\":0,\
                 \"tid\":{},\"s\":\"t\",\"args\":{}}}",
                e.kind.label(),
                us(e.t),
                s.request,
                span_event_json(e)
            ),
        );
    }
}

/// Chrome trace JSON for one run's observability report.
pub fn chrome_trace(obs: &ObsReport) -> String {
    let mut events: Vec<String> = Vec::new();
    for (pid, name) in [(0, "requests"), (1, "scheduler"), (2, "metrics")] {
        push_event(
            &mut events,
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
                 \"args\":{{\"name\":\"{name}\"}}}}"
            ),
        );
    }
    for s in obs.spans.spans() {
        span_slices(&mut events, s);
    }
    for rec in obs.decisions.records() {
        let mut args = format!(
            "{{\"candidates\":{},\"actions\":{},\"cost_us\":{}",
            rec.candidates, rec.actions, rec.cost_us
        );
        if let Some(req) = rec.request {
            args.push_str(&format!(",\"request\":{req}"));
        }
        if let Some(inst) = rec.chosen {
            args.push_str(&format!(",\"chosen\":{inst}"));
        }
        args.push('}');
        push_event(
            &mut events,
            format!(
                "{{\"name\":\"{}:{}\",\"cat\":\"decision\",\"ph\":\"i\",\"ts\":{},\"pid\":1,\
                 \"tid\":{},\"s\":\"t\",\"args\":{args}}}",
                rec.kind.name(),
                esc(&rec.policy),
                us(rec.t),
                rec.kind as usize,
            ),
        );
    }
    for point in obs.registry.series() {
        for (k, v) in &point.values {
            push_event(
                &mut events,
                format!(
                    "{{\"name\":\"{}\",\"ph\":\"C\",\"ts\":{},\"pid\":2,\"tid\":0,\
                     \"args\":{{\"value\":{v}}}}}",
                    esc(k),
                    us(point.t),
                ),
            );
        }
    }
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    out.push_str(&events.join(",\n"));
    out.push_str("\n]}\n");
    out
}

/// JSONL export: one header line, then one line per span, decision,
/// and time-series point.
pub fn jsonl(obs: &ObsReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\"type\":\"obs\",\"enabled\":{},\"seen\":{},\"sampled\":{},\"dropped\":{}}}\n",
        obs.enabled, obs.spans.seen, obs.spans.sampled, obs.spans.dropped
    ));
    for s in obs.spans.spans() {
        let mut line = format!(
            "{{\"type\":\"span\",\"request\":{},\"arrived\":{:.6}",
            s.request, s.arrived
        );
        if let Some((t, inst)) = s.prefill_done {
            line.push_str(&format!(",\"prefill_done\":{t:.6},\"prefill_instance\":{inst}"));
        }
        if let Some((t, inst)) = s.finished {
            line.push_str(&format!(",\"finished\":{t:.6},\"finish_instance\":{inst}"));
        }
        let evs: Vec<String> = s.events.iter().map(span_event_json).collect();
        line.push_str(&format!(",\"events\":[{}]}}\n", evs.join(",")));
        out.push_str(&line);
    }
    for rec in obs.decisions.records() {
        let mut line = format!(
            "{{\"type\":\"decision\",\"t\":{:.6},\"kind\":\"{}\",\"policy\":\"{}\",\
             \"candidates\":{},\"actions\":{},\"cost_us\":{}",
            rec.t,
            rec.kind.name(),
            esc(&rec.policy),
            rec.candidates,
            rec.actions,
            rec.cost_us
        );
        if let Some(req) = rec.request {
            line.push_str(&format!(",\"request\":{req}"));
        }
        if let Some(inst) = rec.chosen {
            line.push_str(&format!(",\"chosen\":{inst}"));
        }
        line.push_str("}\n");
        out.push_str(&line);
    }
    for point in obs.registry.series() {
        let vals: Vec<String> = point
            .values
            .iter()
            .map(|(k, v)| format!("\"{}\":{v}", esc(k)))
            .collect();
        out.push_str(&format!(
            "{{\"type\":\"series\",\"t\":{:.6},\"values\":{{{}}}}}\n",
            point.t,
            vals.join(",")
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::json::{parse, Json};
    use crate::metrics::{TraceEvent, TraceRow};
    use crate::obs::{assemble_report, AttributionLog, MetricsRegistry};

    fn sample_report() -> ObsReport {
        let rows = vec![
            TraceRow { t: 0.0, event: TraceEvent::Arrived { request: 1 } },
            TraceRow { t: 0.5, event: TraceEvent::PrefillDone { request: 1, instance: 0 } },
            TraceRow {
                t: 1.5,
                event: TraceEvent::Migration { request: 1, src: 0, dst: 1, kv_tokens: 32 },
            },
            TraceRow { t: 3.0, event: TraceEvent::Finished { request: 1, instance: 1 } },
        ];
        let mut log = AttributionLog::new(true);
        log.set_now(0.5);
        log.record_dispatch("current_load", 1, 2, 0);
        let mut reg = MetricsRegistry::new(true);
        reg.inc("requests.arrived", 1);
        reg.set_gauge("cluster.kv_frac_max", 0.5);
        reg.sample(1.0);
        assemble_report(true, 42, 1.0, 1024, &rows, reg, log)
    }

    #[test]
    fn chrome_trace_is_valid_json_with_expected_shape() {
        let obs = sample_report();
        let text = chrome_trace(&obs);
        let v = parse(&text).expect("chrome trace must parse");
        assert_eq!(
            v.get("displayTimeUnit"),
            Some(&Json::Str("ms".to_string()))
        );
        let Some(Json::Arr(evs)) = v.get("traceEvents") else {
            panic!("traceEvents must be an array");
        };
        assert!(evs.len() >= 5, "metadata + slices + instants: {}", evs.len());
        let prefill = evs
            .iter()
            .find(|e| e.get("name") == Some(&Json::Str("prefill".to_string())))
            .expect("prefill slice present");
        assert_eq!(prefill.get("ph"), Some(&Json::Str("X".to_string())));
        assert_eq!(prefill.get("ts"), Some(&Json::Num(0.0)));
        assert_eq!(prefill.get("dur"), Some(&Json::Num(500000.0)));
        let decode = evs
            .iter()
            .find(|e| e.get("name") == Some(&Json::Str("decode".to_string())))
            .expect("decode slice present");
        assert_eq!(decode.get("dur"), Some(&Json::Num(2500000.0)));
        assert!(evs
            .iter()
            .any(|e| e.get("name") == Some(&Json::Str("dispatch:current_load".to_string()))));
        assert!(evs
            .iter()
            .any(|e| e.get("ph") == Some(&Json::Str("C".to_string()))));
    }

    #[test]
    fn jsonl_lines_each_parse() {
        let obs = sample_report();
        let text = jsonl(&obs);
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() >= 4, "header + span + decision + series");
        for line in &lines {
            parse(line).expect("every jsonl line parses");
        }
        assert!(lines[0].contains("\"type\":\"obs\""));
        assert!(text.contains("\"type\":\"span\""));
        assert!(text.contains("\"type\":\"decision\""));
        assert!(text.contains("\"type\":\"series\""));
    }

    #[test]
    fn exports_are_deterministic_in_their_inputs() {
        let a = sample_report();
        let b = sample_report();
        assert_eq!(chrome_trace(&a), chrome_trace(&b));
        assert_eq!(jsonl(&a), jsonl(&b));
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
