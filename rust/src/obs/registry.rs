//! String-keyed metrics registry: counters, gauges, and log-bucketed
//! histograms, plus a per-tick time series the drivers sample on the
//! `[obs] sample_every_s` cadence.
//!
//! Dependency-free in the same spirit as `bench::json`; every container
//! is a `BTreeMap` or `Vec`, so iteration order — and therefore every
//! exporter's output — is deterministic (`star analyze` R1 applies to
//! this module). All mutators are no-ops while disabled, which is what
//! the `[obs] enabled = false` bit-for-bit guarantee rests on.

use std::collections::BTreeMap;

use crate::Time;

/// Number of log2 buckets: powers of two from 2^-20 (~1 µs when the unit
/// is seconds) through 2^23 (~8.4 M), one underflow bucket at index 0.
const N_BUCKETS: usize = 44;
/// `log2(value)` offset of bucket index 1.
const BUCKET_OFFSET: i64 = 20;

/// A log2-bucketed histogram with exact count/sum/min/max sidecars.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    buckets: Vec<u64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: vec![0; N_BUCKETS],
        }
    }
}

impl Histogram {
    /// Bucket index for a value: 0 holds non-positive values and
    /// underflow; the last bucket absorbs overflow.
    pub fn bucket_of(v: f64) -> usize {
        if !(v > 0.0) {
            return 0;
        }
        let idx = v.log2().floor() as i64 + BUCKET_OFFSET + 1;
        idx.clamp(0, N_BUCKETS as i64 - 1) as usize
    }

    /// Inclusive upper bound of bucket `i` (`+inf` for the last).
    pub fn bucket_upper(i: usize) -> f64 {
        if i + 1 >= N_BUCKETS {
            f64::INFINITY
        } else {
            2f64.powi((i as i64 - BUCKET_OFFSET) as i32)
        }
    }

    fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.buckets[Self::bucket_of(v)] += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }
}

/// One time-series point: the full counter + gauge snapshot at `t`.
/// Counters are widened to `f64` (exact below 2^53 — far beyond any
/// counter this registry sees in one run).
#[derive(Clone, Debug)]
pub struct SeriesPoint {
    pub t: Time,
    pub values: Vec<(String, f64)>,
}

/// The registry itself. Cheap when disabled: every mutator returns
/// immediately and the report carries empty maps.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    enabled: bool,
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
    series: Vec<SeriesPoint>,
}

impl MetricsRegistry {
    pub fn new(enabled: bool) -> Self {
        MetricsRegistry {
            enabled,
            ..Default::default()
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    #[inline]
    pub fn inc(&mut self, name: &str, by: u64) {
        if !self.enabled {
            return;
        }
        match self.counters.get_mut(name) {
            Some(c) => *c += by,
            None => {
                self.counters.insert(name.to_string(), by);
            }
        }
    }

    #[inline]
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        if !self.enabled {
            return;
        }
        match self.gauges.get_mut(name) {
            Some(g) => *g = v,
            None => {
                self.gauges.insert(name.to_string(), v);
            }
        }
    }

    #[inline]
    pub fn observe(&mut self, name: &str, v: f64) {
        if !self.enabled {
            return;
        }
        match self.histograms.get_mut(name) {
            Some(h) => h.observe(v),
            None => {
                let mut h = Histogram::default();
                h.observe(v);
                self.histograms.insert(name.to_string(), h);
            }
        }
    }

    /// Push one time-series point: the current counters + gauges, in
    /// deterministic (sorted-key) order.
    pub fn sample(&mut self, t: Time) {
        if !self.enabled {
            return;
        }
        let mut values: Vec<(String, f64)> = Vec::new();
        for (k, v) in &self.counters {
            values.push((k.clone(), *v as f64));
        }
        for (k, v) in &self.gauges {
            values.push((k.clone(), *v));
        }
        self.series.push(SeriesPoint { t, values });
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn histograms(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.histograms.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn series(&self) -> &[SeriesPoint] {
        &self.series
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_records_nothing() {
        let mut r = MetricsRegistry::new(false);
        r.inc("a", 3);
        r.set_gauge("g", 1.0);
        r.observe("h", 0.5);
        r.sample(1.0);
        assert_eq!(r.counter("a"), 0);
        assert!(r.gauge("g").is_none());
        assert!(r.histogram("h").is_none());
        assert!(r.series().is_empty());
    }

    #[test]
    fn counters_gauges_histograms_accumulate() {
        let mut r = MetricsRegistry::new(true);
        r.inc("req", 1);
        r.inc("req", 2);
        r.set_gauge("kv", 0.25);
        r.set_gauge("kv", 0.75);
        r.observe("ttft", 0.5);
        r.observe("ttft", 2.0);
        assert_eq!(r.counter("req"), 3);
        assert_eq!(r.gauge("kv"), Some(0.75));
        let h = r.histogram("ttft").expect("recorded");
        assert_eq!(h.count, 2);
        assert!((h.sum - 2.5).abs() < 1e-12);
        assert!((h.mean() - 1.25).abs() < 1e-12);
        assert!((h.min - 0.5).abs() < 1e-12);
        assert!((h.max - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets_are_log2_and_total() {
        // bucket bounds: index i covers (2^(i-21), 2^(i-20)]
        assert_eq!(Histogram::bucket_of(0.0), 0);
        assert_eq!(Histogram::bucket_of(-1.0), 0);
        assert!(Histogram::bucket_of(1.0) < Histogram::bucket_of(2.0));
        assert!(Histogram::bucket_of(2.0) < Histogram::bucket_of(5.0));
        assert_eq!(Histogram::bucket_of(f64::MAX), N_BUCKETS - 1);
        let mut h = Histogram::default();
        for v in [0.001, 0.01, 0.1, 1.0, 10.0] {
            h.observe(v);
        }
        assert_eq!(h.buckets().iter().sum::<u64>(), h.count);
        assert!(Histogram::bucket_upper(N_BUCKETS - 1).is_infinite());
    }

    #[test]
    fn series_snapshots_in_sorted_key_order() {
        let mut r = MetricsRegistry::new(true);
        r.inc("z", 1);
        r.inc("a", 2);
        r.set_gauge("m", 0.5);
        r.sample(1.0);
        r.inc("a", 1);
        r.sample(2.0);
        let s = r.series();
        assert_eq!(s.len(), 2);
        let keys: Vec<&str> = s[0].values.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["a", "z", "m"], "counters then gauges, sorted");
        assert!((s[1].values[0].1 - 3.0).abs() < 1e-12);
        assert!((s[1].t - 2.0).abs() < 1e-12);
    }
}
