//! Mini property-testing harness (offline substitute for proptest).
//!
//! Runs a property over many PRNG-generated cases; on failure it retries
//! with progressively "smaller" generator size hints (shrinking-lite) and
//! reports the failing seed so the case is exactly reproducible:
//!
//! ```text
//! property failed: <msg> (seed=42 case=17 size=8)
//! ```
//!
//! Usage (``ignore``d as a doctest: doctest binaries do not inherit the
//! workspace rpath to libxla_extension — see .cargo/config.toml):
//! ```ignore
//! use star::prop::{property, prop_assert, Gen};
//! property("sorted stays sorted", 200, |g| {
//!     let mut v = g.vec_u64(0, 100);
//!     v.sort_unstable();
//!     let mut w = v.clone();
//!     w.sort_unstable();
//!     prop_assert(v == w, "double sort differs")
//! });
//! ```

use crate::prng::Pcg64;

/// Case generator handed to properties; wraps a PRNG plus a size hint that
/// shrinks when hunting for minimal failures.
pub struct Gen {
    rng: Pcg64,
    /// Soft upper bound on generated collection sizes / magnitudes.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, case: u64, size: usize) -> Self {
        Gen {
            rng: Pcg64::new(seed, case.wrapping_mul(2).wrapping_add(1)),
            size,
        }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }

    /// Vec of u64 with size-hint-bounded length.
    pub fn vec_u64(&mut self, lo: u64, hi: u64) -> Vec<u64> {
        let n = self.usize(0, self.size.max(1));
        (0..n).map(|_| self.u64(lo, hi)).collect()
    }

    pub fn vec_f64(&mut self, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize(0, self.size.max(1));
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }
}

/// Property outcome: Ok or a failure message.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `prop`. Panics (test failure) on the first
/// failing case, after attempting smaller sizes to find a simpler repro.
pub fn property<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let seed = env_seed();
    let base_size = 16usize;
    for case in 0..cases {
        // grow sizes over the run: early cases small, later cases bigger
        let size = base_size + (case as usize * 48) / cases.max(1) as usize;
        let mut g = Gen::new(seed, case, size);
        if let Err(msg) = prop(&mut g) {
            // shrinking-lite: replay with smaller size hints, same stream
            let mut min_fail = (size, msg);
            for s in [8usize, 4, 2, 1] {
                if s >= min_fail.0 {
                    continue;
                }
                let mut g = Gen::new(seed, case, s);
                if let Err(m2) = prop(&mut g) {
                    min_fail = (s, m2);
                }
            }
            panic!(
                "property `{name}` failed: {} (seed={seed} case={case} size={})\n\
                 reproduce with STAR_PROP_SEED={seed}",
                min_fail.1, min_fail.0
            );
        }
    }
}

fn env_seed() -> u64 {
    std::env::var("STAR_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("trivial", 50, |g| {
            count += 1;
            let x = g.u64(0, 100);
            prop_assert(x <= 100, "range violated")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `must-fail` failed")]
    fn failing_property_panics_with_seed() {
        property("must-fail", 50, |g| {
            let v = g.vec_u64(0, 10);
            prop_assert(v.len() < 3, "vec too long")
        });
    }

    #[test]
    fn generator_is_deterministic_per_case() {
        let mut a = Gen::new(1, 5, 16);
        let mut b = Gen::new(1, 5, 16);
        assert_eq!(a.vec_u64(0, 99), b.vec_u64(0, 99));
    }
}
