//! # STAR — decode-phase rescheduling for LLM inference
//!
//! A from-scratch reproduction of *"STAR: Decode-Phase Rescheduling for LLM
//! Inference"* (HPDC '26) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time)** — `python/compile/` authors the star-pico
//!   transformer and its Pallas decode kernels, trains the LLM-native
//!   remaining-length predictor, and AOT-lowers everything to HLO text in
//!   `artifacts/`.
//! * **L3 (this crate, the request path)** — a prefill/decode-disaggregated
//!   serving coordinator: instance pools with continuous batching, a paged
//!   KV-cache manager with OOM semantics, prefill→decode dispatch policies,
//!   and the STAR decode rescheduler (paper Algorithm 1) with live KV
//!   migration; plus an event-driven cluster simulator that reuses the same
//!   policy code for 8–256-instance experiments.
//!
//! Python never runs at serving time: [`runtime`] loads the HLO artifacts
//! through the PJRT C API (`xla` crate) and the binary is self-contained.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to a bench target.

// Part of the unsafe-hygiene gate (`star analyze` R3): any future unsafe
// fn must re-justify each unsafe operation in its body explicitly.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analyze;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod error;
pub mod kvcache;
pub mod metrics;
pub mod obs;
pub mod predictor;
pub mod prng;
pub mod prop;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod workload;

pub use error::{Error, Result};

/// Identifier of a request, unique per run.
pub type RequestId = u64;
/// Index of a decode (or prefill) instance within its pool.
pub type InstanceId = usize;
/// Simulation / wall-clock time in seconds.
pub type Time = f64;
