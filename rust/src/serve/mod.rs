//! Live PD-disaggregated serving runtime (the paper's system, for real).
//!
//! Thread topology (DESIGN.md §7):
//!
//! ```text
//!  clients ──► proxy/coordinator thread ──► prefill worker threads
//!                 ▲      │ dispatch                │ PrefillDone
//!                 │      ▼                         ▼
//!              events  decode instance threads (one per instance)
//!                        each: continuous batcher over StarRuntime
//! ```
//!
//! * Every decode instance owns a fixed-bucket KV buffer plus a paged
//!   [`KvCacheManager`] enforcing the configured token capacity (OOM
//!   semantics identical to the simulator).
//! * The coordinator drives the same [`ControlLoop`] (registry-built
//!   dispatch + reschedule policies, e.g. Algorithm 1 as `"star"`) as the
//!   simulator on worker state reports, and executes migrations by
//!   extracting the KV slot on the source, delaying by the modeled
//!   transfer time, and admitting on the target — the moving request is
//!   paused while the rest of the batch keeps decoding (paper §5.4).
//! * Clients hold a stream handle served by the proxy; migrations are
//!   invisible to them.
//!
//! [`KvCacheManager`]: crate::kvcache::KvCacheManager
//! [`ControlLoop`]: crate::coordinator::ControlLoop

mod instance;
mod server;

pub use instance::{DecodeCommand, DecodeEvent, DecodeInstance, SlotSnapshot};
pub use server::{ServeOutcome, ServeParams, Server};

use crate::workload::{Request, RequestClass, SessionTurn};
use crate::{RequestId, Time};

/// A request as submitted to the live server: trace metadata plus the
/// synthesized prompt bytes.
#[derive(Clone, Debug)]
pub struct LiveRequest {
    pub id: RequestId,
    pub arrival: Time,
    pub prompt: Vec<u8>,
    /// Forced output length (trace-driven runs); None = sample to EOS.
    pub forced_output: Option<u32>,
    pub tag: u8,
    /// Workload class (per-class SLO accounting).
    pub class: RequestClass,
}

impl LiveRequest {
    /// Synthesize the prompt for a trace request in the reasoning-trace
    /// language (tag byte selects the expected-length band).
    pub fn from_trace(req: &Request, max_prompt: usize) -> LiveRequest {
        LiveRequest {
            id: req.id,
            arrival: req.arrival,
            prompt: synth_prompt(req.id, req.tag, req.prompt_len, max_prompt),
            forced_output: Some(req.output_len),
            tag: req.tag,
            class: req.class,
        }
    }

    /// Synthesize a session follow-up turn (same prompt language; the
    /// turn's prompt length already includes the accumulated history).
    pub fn for_session_turn(
        id: RequestId,
        arrival: Time,
        turn: &SessionTurn,
        max_prompt: usize,
    ) -> LiveRequest {
        LiveRequest {
            id,
            arrival,
            prompt: synth_prompt(id, turn.tag, turn.prompt_len, max_prompt),
            forced_output: Some(turn.output_len),
            tag: turn.tag,
            class: turn.class,
        }
    }
}

fn synth_prompt(id: RequestId, tag: u8, prompt_len: u32, max_prompt: usize) -> Vec<u8> {
    let tag_byte = b"abcdefghijklmnop"[(tag & 15) as usize];
    let mut prompt = vec![1u8, b'Q', tag_byte];
    let payload_len = (prompt_len as usize).clamp(1, max_prompt - 4);
    for i in 0..payload_len {
        prompt.push(b'a' + ((id as usize + i * 7) % 26) as u8);
    }
    prompt.push(b'?');
    prompt
}

/// Temperature sampling over logits (the serving-side sampler; greedy at
/// temp == 0).
pub fn sample_token(logits: &[f32], temp: f32, rng: &mut crate::prng::Pcg64) -> usize {
    if temp <= 0.0 {
        let mut best = 0;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        return best;
    }
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let ws: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - mx) / temp) as f64).exp())
        .collect();
    let total: f64 = ws.iter().sum();
    let mut u = rng.next_f64() * total;
    for (i, w) in ws.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    ws.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    #[test]
    fn sample_greedy_at_zero_temp() {
        let mut rng = Pcg64::new(0, 0);
        let logits = vec![0.0, 5.0, 1.0];
        for _ in 0..10 {
            assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
        }
    }

    #[test]
    fn sample_respects_distribution() {
        let mut rng = Pcg64::new(1, 0);
        let logits = vec![2.0, 2.0, -30.0];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[sample_token(&logits, 1.0, &mut rng)] += 1;
        }
        assert_eq!(counts[2], 0);
        assert!(counts[0] > 700 && counts[1] > 700);
    }

    #[test]
    fn live_request_prompt_shape() {
        let req = Request {
            id: 3,
            arrival: 0.0,
            prompt_len: 10,
            output_len: 100,
            tag: 15,
            class: RequestClass::Chat,
        };
        let lr = LiveRequest::from_trace(&req, 128);
        assert_eq!(lr.prompt[0], 1); // BOS
        assert_eq!(lr.prompt[1], b'Q');
        assert_eq!(lr.prompt[2], b'p'); // tag 15
        assert_eq!(*lr.prompt.last().unwrap(), b'?');
        assert!(lr.prompt.len() <= 128);
    }
}
