//! The serving coordinator: proxy + dispatch + STAR rescheduling over the
//! live instance threads.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::instance::{AdmitPayload, DecodeCommand, DecodeEvent, DecodeInstance};
use super::LiveRequest;
use crate::config::{ExperimentConfig, PredictorKind};
use crate::coordinator::{
    admission_watermark, ClusterState, ControlLoop, IncomingRequest, PolicyRegistry, RequestView,
    ReschedulerStats,
};
use crate::costmodel::MigrationCostModel;
use crate::metrics::{
    RequestLatency, RunMetrics, RunningVariance, TraceEvent, TraceRecorder, VarianceOverTime,
};
use crate::runtime::StarRuntime;
use crate::workload::SessionPlan;
use crate::{InstanceId, RequestId, Result, Time};

/// Live-serving parameters (mirrors the simulator's [`SimParams`]). The
/// dispatch / reschedule policies are named by `exp.dispatch_policy` /
/// `exp.reschedule_policy` and built through the server's policy registry.
///
/// [`SimParams`]: crate::sim::SimParams
#[derive(Clone, Debug)]
pub struct ServeParams {
    pub exp: ExperimentConfig,
    pub temperature: f32,
    pub migration: MigrationCostModel,
    /// Hard wall-clock cap for the run.
    pub max_wall_s: f64,
    /// Multi-round session plan (scenario workloads): the server replays
    /// the same per-turn schedule as the simulator — a session's next turn
    /// is submitted a think-time after the previous turn completes, with
    /// its prompt carrying the accumulated history.
    pub sessions: SessionPlan,
}

impl Default for ServeParams {
    fn default() -> Self {
        ServeParams {
            exp: ExperimentConfig::default(),
            temperature: 0.9,
            migration: MigrationCostModel::new_25gbps(4096),
            max_wall_s: 600.0,
            sessions: SessionPlan::default(),
        }
    }
}

/// Results of a live run.
#[derive(Debug)]
pub struct ServeOutcome {
    pub metrics: RunMetrics,
    pub exec_var: VarianceOverTime,
    pub load_var: VarianceOverTime,
    pub recorder: TraceRecorder,
    pub scheduler_stats: ReschedulerStats,
    pub wall_s: f64,
    pub oom_events: u64,
    pub migrations: u64,
}

struct ReqTracker {
    latency: RequestLatency,
    last_token: Option<Instant>,
    tpot_sum: f64,
    tpot_max: f64,
    generated: u32,
    done: bool,
}

/// Per-instance plumbing the coordinator keeps outside the shared
/// [`ClusterState`]: the command channel plus raw KV telemetry for the
/// load-variance metric (scheduler-visible state — slots, EWMAs,
/// reservations — lives in the `ClusterState`).
struct InstanceState {
    cmd: Sender<DecodeCommand>,
    kv_used: u64,
    kv_capacity: u64,
}

/// Live-side multi-round session bookkeeping: the plan plus the realized
/// turn cursor and the queue of spawned-but-not-yet-arrived follow-ups.
struct SessionRt {
    plan: SessionPlan,
    /// request id -> (session, index of its successor turn in the script).
    cursor: HashMap<RequestId, (u32, u32)>,
    /// (arrival wall-time s, request) awaiting injection.
    queue: Vec<(Time, LiveRequest)>,
    next_id: RequestId,
    /// Follow-up requests spawned so far (the run's total request count is
    /// `initial + spawned`).
    spawned: usize,
}

/// The live server. Owns the runtime, the experiment wiring, and the
/// policy registry its control loop builds from.
pub struct Server {
    pub runtime: Arc<StarRuntime>,
    pub params: ServeParams,
    registry: PolicyRegistry,
}

impl Server {
    /// Server with the builtin policy set.
    pub fn new(runtime: Arc<StarRuntime>, params: ServeParams) -> Server {
        Server::with_registry(runtime, params, PolicyRegistry::with_builtins())
    }

    /// Server with a caller-supplied registry (third-party policies).
    pub fn with_registry(
        runtime: Arc<StarRuntime>,
        params: ServeParams,
        registry: PolicyRegistry,
    ) -> Server {
        Server {
            runtime,
            params,
            registry,
        }
    }

    /// Serve a workload to completion; returns aggregated metrics.
    pub fn run(&self, mut requests: Vec<LiveRequest>) -> Result<ServeOutcome> {
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let exp = &self.params.exp;
        let n_requests = requests.len();
        let start = Instant::now();
        let since = |at: Instant| -> Time { at.duration_since(start).as_secs_f64() };

        // --- spawn decode instances ---
        let (ev_tx, ev_rx): (Sender<DecodeEvent>, Receiver<DecodeEvent>) = channel();
        let mut instances: Vec<InstanceState> = Vec::new();
        let mut handles = Vec::new();
        for i in 0..exp.cluster.n_decode {
            let (cmd_tx, cmd_rx) = channel();
            let inst = DecodeInstance {
                id: i,
                runtime: Arc::clone(&self.runtime),
                kv_capacity_tokens: exp.cluster.kv_capacity_tokens,
                block_tokens: exp.cluster.block_tokens,
                max_batch: exp.cluster.max_batch,
                predictor: exp.predictor,
                predict_every_iters: exp.rescheduler.predict_every_iters,
                temperature: self.params.temperature,
                seed: exp.cluster.seed,
            };
            let ev = ev_tx.clone();
            handles.push(std::thread::spawn(move || inst.run(cmd_rx, ev)));
            instances.push(InstanceState {
                cmd: cmd_tx,
                kv_used: 0,
                kv_capacity: exp.cluster.kv_capacity_tokens,
            });
        }

        // --- spawn prefill workers ---
        enum PrefillMsg {
            Done {
                req: LiveRequest,
                kv: crate::runtime::HostTensor,
                hidden: Vec<f32>,
                first_token: i32,
                at: Instant,
            },
            Err(RequestId, String),
        }
        let (pf_in_tx, pf_in_rx) = channel::<LiveRequest>();
        let pf_in_rx = Arc::new(Mutex::new(pf_in_rx));
        let (pf_out_tx, pf_out_rx) = channel::<PrefillMsg>();
        for w in 0..exp.cluster.n_prefill {
            let rx = Arc::clone(&pf_in_rx);
            let tx = pf_out_tx.clone();
            let rt = Arc::clone(&self.runtime);
            let temp = self.params.temperature;
            let seed = exp.cluster.seed ^ (w as u64) << 32;
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::prng::Pcg64::new(seed, 0x50524546);
                loop {
                    let req = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(req) = req else { break };
                    match rt.prefill(&req.prompt) {
                        Ok(out) => {
                            let tok = super::sample_token(&out.logits, temp, &mut rng) as i32;
                            let _ = tx.send(PrefillMsg::Done {
                                req,
                                kv: out.kv,
                                hidden: out.hidden,
                                first_token: tok,
                                at: Instant::now(),
                            });
                        }
                        Err(e) => {
                            let _ = tx.send(PrefillMsg::Err(req.id, e.to_string()));
                        }
                    }
                }
            }));
        }
        drop(pf_out_tx);

        // --- coordinator state ---
        let mut trackers: HashMap<RequestId, ReqTracker> = HashMap::new();
        for r in &requests {
            trackers.insert(
                r.id,
                ReqTracker {
                    latency: RequestLatency {
                        id: r.id,
                        class: r.class,
                        arrival: r.arrival,
                        ..Default::default()
                    },
                    last_token: None,
                    tpot_sum: 0.0,
                    tpot_max: 0.0,
                    generated: 0,
                    done: false,
                },
            );
        }
        let mut session = SessionRt {
            cursor: self
                .params
                .sessions
                .first_turns
                .iter()
                .map(|&(rid, s)| (rid, (s, 0u32)))
                .collect(),
            queue: Vec::new(),
            next_id: requests.iter().map(|r| r.id).max().map_or(0, |m| m + 1),
            spawned: 0,
            plan: self.params.sessions.clone(),
        };
        let mut control =
            ControlLoop::from_experiment(exp, self.params.migration, &self.registry)?;
        let mut recorder = TraceRecorder::new(exp.record_traces);
        let mut exec_var = VarianceOverTime::new();
        let mut load_var = VarianceOverTime::new();
        let mut completed = 0usize;
        let mut failed = 0usize;
        let mut oom_events = 0u64;
        let mut migrations = 0u64;
        // realized output lengths: refines the no-prediction remaining
        // estimate, mirroring the simulator's feed of output_mean / 2
        let mut output_mean = RunningVariance::new();
        let mut migrating: Vec<RequestId> = Vec::new();
        // exact capacity reservations made by migration decisions:
        // request -> (dst instance, reserved tokens)
        let mut reservations: HashMap<RequestId, (InstanceId, u64)> = HashMap::new();
        // admission retry queue: (not_before, payload)
        let mut retries: VecDeque<(Instant, Box<AdmitPayload>)> = VecDeque::new();
        let mut next_arrival = 0usize;
        let mut last_tick = Instant::now();
        let interval = Duration::from_secs_f64(exp.rescheduler.interval_s);

        // scheduler-visible cluster state, shared with the simulator's
        // driver layer: reconciled per instance from authoritative decode
        // reports, with reservation deltas applied at migration
        // decision/delivery time. Dispatch borrows views from it instead
        // of materializing a snapshot per decision.
        let mut state = ClusterState::new(
            exp.cluster.n_decode,
            exp.cluster.kv_capacity_tokens,
            interval.as_secs_f64(),
            exp.rescheduler.initial_avg_iter_s,
            1e-4,
        );
        // the paged allocator rounds capacity down to whole blocks; the
        // scheduler-side watermark guard must see the same number the
        // instances enforce (an idle instance never sends the Report that
        // would otherwise reconcile it)
        let rounded_cap = exp.cluster.kv_capacity_tokens / exp.cluster.block_tokens as u64
            * exp.cluster.block_tokens as u64;
        for i in 0..exp.cluster.n_decode {
            state.set_capacity(i, rounded_cap);
        }

        // --- main loop ---
        while completed + failed < n_requests + session.spawned {
            if start.elapsed().as_secs_f64() > self.params.max_wall_s {
                eprintln!("[serve] wall cap hit: {}s", self.params.max_wall_s);
                break;
            }

            // inject arrivals whose time has come (trace times are wall s)
            let now_s = start.elapsed().as_secs_f64();
            while next_arrival < requests.len() && requests[next_arrival].arrival <= now_s {
                let r = requests[next_arrival].clone();
                recorder.record(now_s, TraceEvent::Arrived { request: r.id });
                pf_in_tx
                    .send(r)
                    .map_err(|_| crate::Error::coordinator("prefill pool died"))?;
                next_arrival += 1;
            }

            // inject session follow-up turns whose think time has elapsed
            // (the simulator replays the same schedule via its
            // SessionFollowUp event)
            let mut i = 0;
            while i < session.queue.len() {
                if session.queue[i].0 <= now_s {
                    let (_, lr) = session.queue.swap_remove(i);
                    recorder.record(now_s, TraceEvent::Arrived { request: lr.id });
                    pf_in_tx
                        .send(lr)
                        .map_err(|_| crate::Error::coordinator("prefill pool died"))?;
                } else {
                    i += 1;
                }
            }

            // re-dispatch parked payloads whose time has come: rejected
            // admissions, OOM recompute victims, and migrated-out requests
            // after their modeled KV-transfer delay (paper §5.4)
            let now_i = Instant::now();
            while let Some((not_before, _)) = retries.front() {
                if *not_before > now_i {
                    break;
                }
                let (_, payload) = retries.pop_front().unwrap();
                migrating.retain(|&id| id != payload.id);
                state.set_migrating(payload.id, false);
                let di = if let Some((dst, amt)) = reservations.remove(&payload.id) {
                    // migration delivery: go to the decided target and
                    // release the exact reservation
                    state.release_inbound(dst, amt);
                    dst
                } else {
                    // rejected admission / OOM recompute: re-dispatch
                    let tokens = payload.pos as u64 + payload.replay.len() as u64;
                    // never-admissible guard (mirrors the simulator's
                    // stranded-request fix): a payload whose KV cannot
                    // pass the admission watermark on ANY instance would
                    // bounce through this retry queue forever
                    let admissible = (0..state.n_instances()).any(|i| {
                        tokens.max(1) <= admission_watermark(state.stats(i).kv_capacity_tokens())
                    });
                    if !admissible {
                        match trackers.get_mut(&payload.id) {
                            Some(t) if !t.done => {
                                t.done = true;
                                failed += 1;
                            }
                            _ => {}
                        }
                        eprintln!(
                            "[serve] request {} ({tokens} KV tokens) can never pass the \
                             admission watermark: failed terminally",
                            payload.id
                        );
                        continue;
                    }
                    control.dispatch(
                        &state.view(),
                        &IncomingRequest {
                            id: payload.id,
                            tokens,
                            predicted_remaining: payload.predicted_remaining,
                        },
                    )
                };
                let _ = instances[di].cmd.send(DecodeCommand::Admit(payload));
            }

            // prefill completions (non-blocking)
            while let Ok(msg) = pf_out_rx.try_recv() {
                match msg {
                    PrefillMsg::Err(id, e) => {
                        eprintln!("[serve] prefill failed for {id}: {e}");
                        failed += 1;
                        trackers.get_mut(&id).unwrap().done = true;
                    }
                    PrefillMsg::Done {
                        req,
                        kv,
                        hidden,
                        first_token,
                        at,
                    } => {
                        let t = trackers.get_mut(&req.id).unwrap();
                        t.latency.prefill_done = Some(since(at));
                        t.latency.first_token = Some(since(at));
                        t.last_token = Some(at);
                        recorder.record(
                            since(at),
                            TraceEvent::PrefillDone {
                                request: req.id,
                                instance: 0,
                            },
                        );
                        // initial prediction (drives PredictedLoad dispatch
                        // and seeds the rescheduler's view)
                        let pred = match self.params.exp.predictor {
                            PredictorKind::None => None,
                            PredictorKind::LlmNative => self
                                .runtime
                                .predict_remaining(&hidden)
                                .ok()
                                .map(|v| v[0] as f64),
                            PredictorKind::Oracle | PredictorKind::Binned(_) => {
                                req.forced_output.map(|o| o as f64)
                            }
                        };
                        let di = control.dispatch(
                            &state.view(),
                            &IncomingRequest {
                                id: req.id,
                                tokens: req.prompt.len() as u64,
                                predicted_remaining: pred,
                            },
                        );
                        let payload = Box::new(AdmitPayload {
                            id: req.id,
                            kv,
                            pos: req.prompt.len() as i32,
                            next_token: first_token,
                            generated: 0,
                            forced_remaining: req.forced_output,
                            replay: Default::default(),
                            predicted_remaining: pred,
                        });
                        let _ = instances[di].cmd.send(DecodeCommand::Admit(payload));
                    }
                }
            }

            // decode events (block briefly so the loop doesn't spin)
            match ev_rx.recv_timeout(Duration::from_millis(2)) {
                Err(RecvTimeoutError::Disconnected) => break,
                Err(RecvTimeoutError::Timeout) => {}
                Ok(first) => {
                    let mut pending = Some(first);
                    while let Some(ev) = pending.take() {
                        self.handle_event(
                            ev,
                            &since,
                            &mut trackers,
                            &mut instances,
                            &mut state,
                            &mut migrating,
                            &mut reservations,
                            &mut recorder,
                            &mut retries,
                            &mut completed,
                            &mut oom_events,
                            &mut output_mean,
                            &mut session,
                        );
                        pending = ev_rx.try_recv().ok();
                    }
                }
            }

            // scheduler tick (Algorithm 1)
            if last_tick.elapsed() >= interval {
                last_tick = Instant::now();
                let now_s = start.elapsed().as_secs_f64();
                let iters: Vec<f64> = (0..instances.len())
                    .map(|i| {
                        let s = state.stats(i);
                        if s.batch_size() == 0 {
                            0.0
                        } else {
                            s.ewma_iter_ms()
                        }
                    })
                    .collect();
                exec_var.snapshot(now_s, &iters);
                let loads: Vec<f64> = instances.iter().map(|s| s.kv_used as f64).collect();
                load_var.snapshot(now_s, &loads);
                for (i, st) in instances.iter().enumerate() {
                    recorder.record(
                        now_s,
                        TraceEvent::KvSample {
                            instance: i,
                            kv_frac: st.kv_used as f64 / st.kv_capacity.max(1) as f64,
                            tokens: st.kv_used,
                            batch: state.stats(i).batch_size(),
                        },
                    );
                }
                if control.rescheduling_enabled() {
                    control.observe_avg_iter_s(state.avg_iter_s());
                    if output_mean.count() > 10 {
                        control.observe_default_remaining(output_mean.mean() / 2.0);
                    }
                    let decisions = control.reschedule(&state.view());
                    for d in decisions {
                        migrations += 1;
                        migrating.push(d.request);
                        state.set_migrating(d.request, true);
                        state.reserve_inbound(d.dst, d.kv_tokens);
                        reservations.insert(d.request, (d.dst, d.kv_tokens));
                        recorder.record(
                            now_s,
                            TraceEvent::Migration {
                                request: d.request,
                                src: d.src,
                                dst: d.dst,
                                kv_tokens: d.kv_tokens,
                            },
                        );
                        let _ = instances[d.src]
                            .cmd
                            .send(DecodeCommand::MigrateOut { id: d.request });
                    }
                }
            }

        }

        // shutdown
        for st in &instances {
            let _ = st.cmd.send(DecodeCommand::Shutdown);
        }
        drop(pf_in_tx);
        for h in handles {
            let _ = h.join();
        }

        let wall = start.elapsed().as_secs_f64();
        let mut metrics = RunMetrics {
            completed: Vec::new(),
            duration: wall,
            oom_events,
            migrations,
        };
        for t in trackers.into_values() {
            if t.latency.finished.is_some() {
                metrics.completed.push(t.latency);
            }
        }
        Ok(ServeOutcome {
            metrics,
            exec_var,
            load_var,
            recorder,
            scheduler_stats: control.stats(),
            wall_s: wall,
            oom_events,
            migrations,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_event(
        &self,
        ev: DecodeEvent,
        since: &dyn Fn(Instant) -> Time,
        trackers: &mut HashMap<RequestId, ReqTracker>,
        instances: &mut [InstanceState],
        state: &mut ClusterState,
        migrating: &mut Vec<RequestId>,
        reservations: &mut HashMap<RequestId, (InstanceId, u64)>,
        recorder: &mut TraceRecorder,
        retries: &mut VecDeque<(Instant, Box<AdmitPayload>)>,
        completed: &mut usize,
        oom_events: &mut u64,
        output_mean: &mut RunningVariance,
        session: &mut SessionRt,
    ) {
        match ev {
            DecodeEvent::Token { id, at, .. } => {
                if let Some(t) = trackers.get_mut(&id) {
                    if let Some(prev) = t.last_token {
                        let gap = at.duration_since(prev).as_secs_f64();
                        t.tpot_sum += gap;
                        t.tpot_max = t.tpot_max.max(gap);
                    }
                    t.last_token = Some(at);
                    t.generated += 1;
                    if t.latency.first_token.is_none() {
                        t.latency.first_token = Some(since(at));
                    }
                }
            }
            DecodeEvent::Finished {
                instance,
                id,
                generated,
                at,
            } => {
                // a migration decided for a request that finished before
                // the MigrateOut command reached its slot is silently
                // dropped by the instance ("stale decision"): release the
                // reservation here or it leaks for the rest of the run
                if let Some((dst, amt)) = reservations.remove(&id) {
                    state.release_inbound(dst, amt);
                    migrating.retain(|&m| m != id);
                }
                let mut finished_now = false;
                if let Some(t) = trackers.get_mut(&id) {
                    if !t.done {
                        t.done = true;
                        finished_now = true;
                        *completed += 1;
                        output_mean.push(generated as f64);
                        t.latency.finished = Some(since(at));
                        t.latency.output_tokens = generated;
                        t.latency.finalize_tpot(t.generated, t.tpot_sum, t.tpot_max);
                        recorder.record(
                            since(at),
                            TraceEvent::Finished {
                                request: id,
                                instance,
                            },
                        );
                    }
                }
                // spawn the session's next turn: it arrives a think-time
                // after THIS completion, prompt carrying the accumulated
                // history (same schedule the simulator realizes)
                if finished_now {
                    let cursor = session.cursor.get(&id).copied();
                    if let Some((s, k)) = cursor {
                        let turn = session
                            .plan
                            .scripts
                            .get(s as usize)
                            .and_then(|sc| sc.get(k as usize))
                            .cloned();
                        if let Some(turn) = turn {
                            let nid = session.next_id;
                            session.next_id += 1;
                            let arrival = since(at) + turn.think_time_s;
                            let lr = LiveRequest::for_session_turn(
                                nid,
                                arrival,
                                &turn,
                                self.runtime.meta.max_prompt,
                            );
                            trackers.insert(
                                nid,
                                ReqTracker {
                                    latency: RequestLatency {
                                        id: nid,
                                        class: turn.class,
                                        arrival,
                                        ..Default::default()
                                    },
                                    last_token: None,
                                    tpot_sum: 0.0,
                                    tpot_max: 0.0,
                                    generated: 0,
                                    done: false,
                                },
                            );
                            session.cursor.insert(nid, (s, k + 1));
                            session.queue.push((arrival, lr));
                            session.spawned += 1;
                        }
                    }
                }
            }
            DecodeEvent::AdmitRejected { payload, .. } => {
                retries.push_back((Instant::now() + std::time::Duration::from_millis(25), payload));
            }
            DecodeEvent::MigratedOut { payload, .. } => {
                // transfer delay: park in the retry queue; the retry path
                // re-dispatches onto the (stale-aware) freshest snapshot,
                // which for a migration is the chosen dst — the reschedule
                // decision already reserved capacity there.
                let delay = self
                    .params
                    .migration
                    .transfer_time(payload.pos as u64);
                if let Some(t) = trackers.get_mut(&payload.id) {
                    t.latency.migrations += 1;
                }
                retries.push_back((
                    Instant::now() + std::time::Duration::from_secs_f64(delay),
                    payload,
                ));
            }
            DecodeEvent::Oom { instance, victims, at } => {
                *oom_events += 1;
                recorder.record(
                    since(at),
                    TraceEvent::Oom {
                        instance,
                        victims: victims.len(),
                    },
                );
                for v in victims {
                    if let Some(t) = trackers.get_mut(&v.id) {
                        t.latency.hit_oom = true;
                    }
                    retries.push_back((Instant::now(), v));
                }
            }
            DecodeEvent::Report {
                instance,
                slots,
                ewma_iter_ms,
                kv_used,
                kv_capacity,
                ..
            } => {
                // authoritative per-instance reconciliation: the decode
                // thread owns the truth; fold its report into the shared
                // scheduler state (O(slots of this instance), not
                // O(cluster))
                let views = slots
                    .iter()
                    .map(|s| RequestView {
                        id: s.id,
                        tokens: s.tokens,
                        predicted_remaining: s.predicted_remaining,
                        migrating: migrating.contains(&s.id),
                    })
                    .collect();
                state.sync_instance(instance, views);
                state.set_iter_ewma(instance, ewma_iter_ms);
                state.set_capacity(instance, kv_capacity);
                let st = &mut instances[instance];
                st.kv_used = kv_used;
                st.kv_capacity = kv_capacity;
            }
        }
    }
}
