//! The serving coordinator: proxy + dispatch + STAR rescheduling over the
//! live instance threads.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::instance::{AdmitPayload, DecodeCommand, DecodeEvent, DecodeInstance};
use super::LiveRequest;
use crate::config::{ExperimentConfig, PredictorKind};
use crate::coordinator::{
    admission_watermark, ClusterState, ControlLoop, HardwareProfile, IncomingRequest, Lifecycle,
    PolicyRegistry, PoolRole, PoolStats, RateMeter, RequestView, ReschedulerStats, ScaleRecord,
    ScalingAction,
};
use crate::costmodel::MigrationCostModel;
use crate::kvcache::{CacheContext, CachePolicyRegistry, CacheReport, PrefixCache};
use crate::metrics::{
    PoolSample, RequestLatency, RunMetrics, RunningVariance, TraceEvent, TraceRecorder,
    VarianceOverTime,
};
use crate::obs::{MetricsRegistry, ObsReport};
use crate::predictor::{PredSample, Prediction, Scorecard};
use crate::runtime::StarRuntime;
use crate::sim::ReliabilityReport;
use crate::workload::SessionPlan;
use crate::{InstanceId, RequestId, Result, Time};

/// Live-serving parameters (mirrors the simulator's [`SimParams`]). The
/// dispatch / reschedule policies are named by `exp.dispatch_policy` /
/// `exp.reschedule_policy` and built through the server's policy registry.
///
/// [`SimParams`]: crate::sim::SimParams
#[derive(Clone, Debug)]
pub struct ServeParams {
    pub exp: ExperimentConfig,
    pub temperature: f32,
    pub migration: MigrationCostModel,
    /// Hard wall-clock cap for the run.
    pub max_wall_s: f64,
    /// Multi-round session plan (scenario workloads): the server replays
    /// the same per-turn schedule as the simulator — a session's next turn
    /// is submitted a think-time after the previous turn completes, with
    /// its prompt carrying the accumulated history.
    pub sessions: SessionPlan,
}

impl Default for ServeParams {
    fn default() -> Self {
        ServeParams {
            exp: ExperimentConfig::default(),
            temperature: 0.9,
            migration: MigrationCostModel::new_25gbps(4096),
            max_wall_s: 600.0,
            sessions: SessionPlan::default(),
        }
    }
}

/// Results of a live run.
#[derive(Debug)]
pub struct ServeOutcome {
    pub metrics: RunMetrics,
    pub exec_var: VarianceOverTime,
    pub load_var: VarianceOverTime,
    pub recorder: TraceRecorder,
    pub scheduler_stats: ReschedulerStats,
    pub wall_s: f64,
    pub oom_events: u64,
    pub migrations: u64,
    /// Elastic pool-size timeline, one sample per scale interval.
    pub pool_timeline: Vec<PoolSample>,
    /// Executed scaling actions, in decision order.
    pub scale_actions: Vec<ScaleRecord>,
    /// Predictor calibration: signed error + MAE per progress bucket,
    /// accumulated at request completion (empty under `none`).
    pub scorecard: Scorecard,
    /// Prefix-cache effectiveness counters (all zeros, `enabled == false`
    /// under the `none` policy). The live cache is coordinator-side
    /// accounting: it steers session-affinity routing and competes for
    /// headroom like the simulator's, but the instance-side prefill still
    /// computes the full prompt.
    pub cache: CacheReport,
    /// Fault/reliability accounting, mirroring the simulator's report so
    /// both drivers expose the same outcome shape. The live driver does
    /// not inject faults (instance threads either run or the whole
    /// process aborts), so this is always the default (empty) report.
    pub reliability: ReliabilityReport,
    /// Observability output (`[obs]` table, `star trace`): sampled
    /// request spans, the metrics registry, and the decision log —
    /// the same shape the simulator's `SimReport` carries. Decision
    /// records here additionally carry measured `cost_us` (serve is
    /// the wall-clock layer). Default-shaped for obs-disabled runs.
    pub obs: ObsReport,
}

struct ReqTracker {
    latency: RequestLatency,
    last_token: Option<Instant>,
    tpot_sum: f64,
    tpot_max: f64,
    generated: u32,
    done: bool,
    /// Estimates issued for this request (initial + repredictions seen in
    /// instance reports), folded into the run's calibration scorecard at
    /// completion.
    pred_log: Vec<PredSample>,
    /// Issue point of the last logged estimate (dedupe key: reports
    /// repeat each estimate every step, but `issued_at_iter` is strictly
    /// increasing per reprediction — deduping on the VALUE would drop a
    /// distinct reprediction that happens to return the same number,
    /// exactly the stuck-predictor case the scorecard exists to expose).
    last_pred_iter: Option<u64>,
}

/// Per-instance plumbing the coordinator keeps outside the shared
/// [`ClusterState`]: the command channel plus raw KV telemetry for the
/// load-variance metric (scheduler-visible state — slots, EWMAs,
/// reservations — lives in the `ClusterState`).
struct InstanceState {
    cmd: Sender<DecodeCommand>,
    kv_used: u64,
    kv_capacity: u64,
    /// Elastic lifecycle (mirrored into the shared `ClusterState`).
    lifecycle: Lifecycle,
    /// Re-role as a prefill worker once this drain completes.
    flip_to_prefill: bool,
}

/// Message from a prefill worker thread back to the coordinator.
enum PrefillMsg {
    Done {
        req: LiveRequest,
        kv: crate::runtime::HostTensor,
        hidden: Vec<f32>,
        first_token: i32,
        at: Instant,
    },
    Err {
        id: RequestId,
        prompt_tokens: u64,
        msg: String,
    },
}

/// One prefill worker thread, as the coordinator sees it. Workers share
/// one request channel, so "draining" a worker is just raising its stop
/// flag: it finishes its current request and exits; queued work stays in
/// the shared channel for the remaining workers.
struct PrefillWorker {
    stop: Arc<AtomicBool>,
}

impl PrefillWorker {
    fn is_active(&self) -> bool {
        !self.stop.load(Ordering::Relaxed)
    }
}

/// Live-side multi-round session bookkeeping: the plan plus the realized
/// turn cursor and the queue of spawned-but-not-yet-arrived follow-ups.
struct SessionRt {
    plan: SessionPlan,
    /// request id -> (session, index of its successor turn in the script).
    cursor: BTreeMap<RequestId, (u32, u32)>,
    /// (arrival wall-time s, request) awaiting injection.
    queue: Vec<(Time, LiveRequest)>,
    next_id: RequestId,
    /// Follow-up requests spawned so far (the run's total request count is
    /// `initial + spawned`).
    spawned: usize,
}

/// Reconcile the shared state's cached-token mirror against the cache's
/// per-instance totals. The cache mutates internally (supersede on insert,
/// expiry inside `take`, budget evictions), so callers resync after every
/// mutation instead of tracking deltas.
fn sync_cached_mirror(state: &mut ClusterState, cache: &PrefixCache) {
    for i in 0..state.n_instances() {
        let want = cache.cached_on(i);
        let have = state.stats(i).cached_tokens();
        match want.cmp(&have) {
            std::cmp::Ordering::Greater => state.add_cached(i, want - have),
            std::cmp::Ordering::Less => state.sub_cached(i, have - want),
            std::cmp::Ordering::Equal => {}
        }
    }
}

/// The live server. Owns the runtime, the experiment wiring, and the
/// policy registry its control loop builds from.
pub struct Server {
    pub runtime: Arc<StarRuntime>,
    pub params: ServeParams,
    registry: PolicyRegistry,
}

impl Server {
    /// Server with the builtin policy set.
    pub fn new(runtime: Arc<StarRuntime>, params: ServeParams) -> Server {
        Server::with_registry(runtime, params, PolicyRegistry::with_builtins())
    }

    /// Server with a caller-supplied registry (third-party policies).
    pub fn with_registry(
        runtime: Arc<StarRuntime>,
        params: ServeParams,
        registry: PolicyRegistry,
    ) -> Server {
        Server {
            runtime,
            params,
            registry,
        }
    }

    /// Hardware profile for decode slot `id`: the experiment's fleet mix
    /// cycled over slot ids (same rule the simulator applies), or the
    /// homogeneous default when no `[fleet]` is configured.
    fn decode_profile(&self, id: InstanceId) -> HardwareProfile {
        self.params
            .exp
            .fleet
            .as_ref()
            .map_or(HardwareProfile::default(), |f| f.profile(id))
    }

    /// Spawn one decode-instance thread (initial pool and elastic joins).
    /// `pred_kind` is the live execution path derived once from the
    /// experiment's predictor registry name. The slot's KV capacity is the
    /// cluster baseline scaled by its hardware profile's `mem_mult`
    /// (speed_mult is a modeled-time knob and has no live analogue — the
    /// thread runs as fast as the substrate allows).
    fn spawn_decode_thread(
        &self,
        id: InstanceId,
        pred_kind: PredictorKind,
        ev_tx: &Sender<DecodeEvent>,
    ) -> (InstanceState, std::thread::JoinHandle<()>) {
        let exp = &self.params.exp;
        let profile = self.decode_profile(id);
        let kv_capacity =
            (exp.cluster.kv_capacity_tokens as f64 * profile.mem_mult).round() as u64;
        let (cmd_tx, cmd_rx) = channel();
        let inst = DecodeInstance {
            id,
            runtime: Arc::clone(&self.runtime),
            kv_capacity_tokens: kv_capacity,
            block_tokens: exp.cluster.block_tokens,
            max_batch: exp.cluster.max_batch,
            predictor: pred_kind,
            predict_every_iters: exp.rescheduler.predict_every_iters,
            temperature: self.params.temperature,
            seed: exp.cluster.seed,
        };
        let ev = ev_tx.clone();
        let handle = std::thread::spawn(move || inst.run(cmd_rx, ev));
        (
            InstanceState {
                cmd: cmd_tx,
                kv_used: 0,
                kv_capacity,
                lifecycle: Lifecycle::Active,
                flip_to_prefill: false,
            },
            handle,
        )
    }

    /// Spawn one prefill worker thread (initial pool and elastic joins).
    /// Workers consume the shared request channel; the returned stop flag
    /// drains the worker (finish the current request, then exit).
    fn spawn_prefill_worker(
        &self,
        widx: u64,
        rx: Arc<Mutex<Receiver<LiveRequest>>>,
        tx: Sender<PrefillMsg>,
    ) -> (PrefillWorker, std::thread::JoinHandle<()>) {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_w = Arc::clone(&stop);
        let rt = Arc::clone(&self.runtime);
        let temp = self.params.temperature;
        let seed = self.params.exp.cluster.seed ^ (widx << 32);
        let handle = std::thread::spawn(move || {
            let mut rng = crate::prng::Pcg64::new(seed, 0x50524546);
            loop {
                if stop_w.load(Ordering::Relaxed) {
                    break;
                }
                let req = {
                    let guard = rx.lock().expect("prefill rx mutex poisoned: a worker panicked");
                    guard.recv_timeout(Duration::from_millis(20))
                };
                let req = match req {
                    Ok(r) => r,
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => break,
                };
                match rt.prefill(&req.prompt) {
                    Ok(out) => {
                        let tok = super::sample_token(&out.logits, temp, &mut rng) as i32;
                        let _ = tx.send(PrefillMsg::Done {
                            req,
                            kv: out.kv,
                            hidden: out.hidden,
                            first_token: tok,
                            at: Instant::now(),
                        });
                    }
                    Err(e) => {
                        let _ = tx.send(PrefillMsg::Err {
                            id: req.id,
                            prompt_tokens: req.prompt.len() as u64,
                            msg: e.to_string(),
                        });
                    }
                }
            }
        });
        (PrefillWorker { stop }, handle)
    }

    /// Serve a workload to completion; returns aggregated metrics.
    pub fn run(&self, mut requests: Vec<LiveRequest>) -> Result<ServeOutcome> {
        requests.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .expect("trace arrivals are finite")
        });
        let exp = &self.params.exp;
        let n_requests = requests.len();
        // the live execution path for the configured predictor name. The
        // REGISTRY is the authoritative grammar (same one the simulator
        // builds from and validate() checks against): gate on it first so
        // a name the sim would reject (e.g. `binned9`, which
        // PredictorKind::parse alone would happily accept) fails here too
        // instead of silently serving, and custom sim-only registrations
        // error with the builtin candidate list rather than a parse error.
        let pred_reg = crate::predictor::PredictorRegistry::with_builtins();
        if !pred_reg.has(&exp.predictor) {
            return Err(crate::Error::config(format!(
                "unknown predictor `{}` for the live path (known: {})",
                exp.predictor,
                pred_reg.names().join("|")
            )));
        }
        let pred_kind = PredictorKind::parse(&exp.predictor)?;
        let start = Instant::now();
        let since = |at: Instant| -> Time { at.duration_since(start).as_secs_f64() };

        // --- spawn decode instances ---
        let (ev_tx, ev_rx): (Sender<DecodeEvent>, Receiver<DecodeEvent>) = channel();
        let mut instances: Vec<InstanceState> = Vec::new();
        let mut handles = Vec::new();
        for i in 0..exp.cluster.n_decode {
            let (st, handle) = self.spawn_decode_thread(i, pred_kind, &ev_tx);
            handles.push(handle);
            instances.push(st);
        }

        // --- spawn prefill workers ---
        let (pf_in_tx, pf_in_rx) = channel::<LiveRequest>();
        let pf_in_rx = Arc::new(Mutex::new(pf_in_rx));
        let (pf_out_tx, pf_out_rx) = channel::<PrefillMsg>();
        let mut prefill_workers: Vec<PrefillWorker> = Vec::new();
        let mut next_prefill_seed = 0u64;
        for _ in 0..exp.cluster.n_prefill {
            let (worker, handle) = self.spawn_prefill_worker(
                next_prefill_seed,
                Arc::clone(&pf_in_rx),
                pf_out_tx.clone(),
            );
            next_prefill_seed += 1;
            handles.push(handle);
            prefill_workers.push(worker);
        }

        // --- coordinator state ---
        let mut trackers: BTreeMap<RequestId, ReqTracker> = BTreeMap::new();
        for r in &requests {
            trackers.insert(
                r.id,
                ReqTracker {
                    latency: RequestLatency {
                        id: r.id,
                        class: r.class,
                        arrival: r.arrival,
                        prompt_tokens: r.prompt.len() as u32,
                        suffix_tokens: r.prompt.len() as u32,
                        ..Default::default()
                    },
                    last_token: None,
                    tpot_sum: 0.0,
                    tpot_max: 0.0,
                    generated: 0,
                    done: false,
                    pred_log: Vec::new(),
                    last_pred_iter: None,
                },
            );
        }
        let mut session = SessionRt {
            cursor: self
                .params
                .sessions
                .first_turns
                .iter()
                .map(|&(rid, s)| (rid, (s, 0u32)))
                .collect(),
            queue: Vec::new(),
            next_id: requests.iter().map(|r| r.id).max().map_or(0, |m| m + 1),
            spawned: 0,
            plan: self.params.sessions.clone(),
        };
        let mut control =
            ControlLoop::from_experiment(exp, self.params.migration, &self.registry)?;
        // coordinator-side prefix cache (same registry + config the sim
        // builds from): drives session-affinity routing and competes for
        // KV headroom via the ClusterState mirror. The live instance-side
        // prefill still computes the full prompt — physical KV reuse is a
        // sim-level model — so reuse counters here describe routing, not
        // skipped FLOPs.
        let cache_policy = CachePolicyRegistry::with_builtins().build(
            &exp.kvcache.policy,
            &CacheContext {
                conservative_q: exp.predictor_conservative_q,
            },
        )?;
        let mut prefix_cache =
            PrefixCache::new(cache_policy, exp.kvcache.budget_tokens, exp.kvcache.ttl_s);
        // spans need the event rows even when plain trace recording is
        // off: obs force-enables the recorder (recording is passive)
        let mut recorder = TraceRecorder::new(exp.record_traces || exp.obs.enabled);
        // `[obs]` registry + series clock (run-clock seconds); every
        // mutator is a no-op while disabled
        let mut obs_registry = MetricsRegistry::new(exp.obs.enabled);
        let mut next_obs_sample = 0.0f64;
        let mut exec_var = VarianceOverTime::new();
        let mut load_var = VarianceOverTime::new();
        let mut completed = 0usize;
        let mut failed = 0usize;
        let mut oom_events = 0u64;
        let mut migrations = 0u64;
        // online calibration: folded at each completion from the per-
        // request prediction logs (same definition as the simulator's)
        let mut scorecard = Scorecard::new();
        // realized output lengths: refines the no-prediction remaining
        // estimate, mirroring the simulator's feed of output_mean / 2
        let mut output_mean = RunningVariance::new();
        let mut migrating: Vec<RequestId> = Vec::new();
        // exact capacity reservations made by migration decisions:
        // request -> (dst instance, reserved tokens)
        let mut reservations: BTreeMap<RequestId, (InstanceId, u64)> = BTreeMap::new();
        // admission retry queue: (not_before, payload)
        let mut retries: VecDeque<(Instant, Box<AdmitPayload>)> = VecDeque::new();
        let mut next_arrival = 0usize;
        let mut last_tick = Instant::now();
        let interval = Duration::from_secs_f64(exp.rescheduler.interval_s);

        // --- elastic-pool bookkeeping ---
        let elastic = exp.elastic.clone();
        let mut last_scale = Instant::now();
        let scale_interval = Duration::from_secs_f64(elastic.scale_interval_s);
        let ready_after = |delay_s: f64| Instant::now() + Duration::from_secs_f64(delay_s);
        let mut pool_timeline: Vec<PoolSample> = Vec::new();
        let mut scale_log: Vec<ScaleRecord> = Vec::new();
        // warmed-up instances waiting to join: (ready time, role)
        let mut pending_ready: Vec<(Instant, PoolRole)> = Vec::new();
        let mut prefill_provisioning = 0usize;
        let mut decode_provisioning = 0usize;
        // prefill backlog: requests handed to the worker pool and not yet
        // reported back (the shared channel is invisible, so count ends)
        let mut prefill_inflight_reqs = 0usize;
        let mut prefill_inflight_tokens = 0u64;
        // shared arrival / prefill-service rate meter (same definition
        // as the simulator's — the predictive policies' measured inputs)
        let mut rates = RateMeter::default();
        // stopped workers count as Draining for one scale interval so
        // the guard's one-in-flight-transition rule covers live prefill
        // drains too (the worker may still be finishing a request; its
        // exit is not observable without joining the thread)
        let mut prefill_drains: Vec<Instant> = Vec::new();

        // scheduler-visible cluster state, shared with the simulator's
        // driver layer: reconciled per instance from authoritative decode
        // reports, with reservation deltas applied at migration
        // decision/delivery time. Dispatch borrows views from it instead
        // of materializing a snapshot per decision.
        let mut state = ClusterState::new(
            exp.cluster.n_decode,
            exp.cluster.kv_capacity_tokens,
            interval.as_secs_f64(),
            exp.rescheduler.initial_avg_iter_s,
            1e-4,
        );
        // the paged allocator rounds capacity down to whole blocks; the
        // scheduler-side watermark guard must see the same number the
        // instances enforce (an idle instance never sends the Report that
        // would otherwise reconcile it). Capacities are per-instance under
        // a heterogeneous fleet (mem_mult-scaled at spawn).
        let round_cap =
            |cap: u64| cap / exp.cluster.block_tokens as u64 * exp.cluster.block_tokens as u64;
        for i in 0..exp.cluster.n_decode {
            state.set_capacity(i, round_cap(instances[i].kv_capacity));
            state.set_profile(i, self.decode_profile(i));
        }

        // --- main loop ---
        while completed + failed < n_requests + session.spawned {
            if start.elapsed().as_secs_f64() > self.params.max_wall_s {
                eprintln!("[serve] wall cap hit: {}s", self.params.max_wall_s);
                break;
            }

            // inject arrivals whose time has come (trace times are wall s)
            let now_s = start.elapsed().as_secs_f64();

            // `[obs]` series sampling on its own cadence (run-clock s)
            if obs_registry.enabled() && now_s >= next_obs_sample {
                let active = instances
                    .iter()
                    .filter(|i| i.lifecycle == Lifecycle::Active)
                    .count();
                let kv_used: u64 = instances
                    .iter()
                    .filter(|i| i.lifecycle != Lifecycle::Retired)
                    .map(|i| i.kv_used)
                    .sum();
                let batch: usize = (0..state.n_instances())
                    .map(|i| state.stats(i).batch_size())
                    .sum();
                obs_registry.set_gauge("decode.active_instances", active as f64);
                obs_registry.set_gauge("kv.used_tokens", kv_used as f64);
                obs_registry.set_gauge("batch.running", batch as f64);
                obs_registry.set_gauge("prefill.queued_reqs", prefill_inflight_reqs as f64);
                obs_registry.sample(now_s);
                next_obs_sample = now_s + exp.obs.sample_every_s;
            }

            while next_arrival < requests.len() && requests[next_arrival].arrival <= now_s {
                let r = requests[next_arrival].clone();
                recorder.record(now_s, TraceEvent::Arrived { request: r.id });
                obs_registry.inc("requests.arrived", 1);
                prefill_inflight_reqs += 1;
                prefill_inflight_tokens += r.prompt.len() as u64;
                rates.on_arrival(r.prompt.len() as u64);
                pf_in_tx
                    .send(r)
                    .map_err(|_| crate::Error::coordinator("prefill pool died"))?;
                next_arrival += 1;
            }

            // inject session follow-up turns whose think time has elapsed
            // (the simulator replays the same schedule via its
            // SessionFollowUp event)
            let mut i = 0;
            while i < session.queue.len() {
                if session.queue[i].0 <= now_s {
                    let (_, lr) = session.queue.swap_remove(i);
                    recorder.record(now_s, TraceEvent::Arrived { request: lr.id });
                    obs_registry.inc("requests.arrived", 1);
                    prefill_inflight_reqs += 1;
                    prefill_inflight_tokens += lr.prompt.len() as u64;
                    rates.on_arrival(lr.prompt.len() as u64);
                    pf_in_tx
                        .send(lr)
                        .map_err(|_| crate::Error::coordinator("prefill pool died"))?;
                } else {
                    i += 1;
                }
            }

            // warmed-up elastic instances join their pools
            let now_i = Instant::now();
            let mut j = 0;
            while j < pending_ready.len() {
                if pending_ready[j].0 > now_i {
                    j += 1;
                    continue;
                }
                let (_, role) = pending_ready.swap_remove(j);
                match role {
                    PoolRole::Decode => {
                        decode_provisioning -= 1;
                        let id = instances.len();
                        // elastic joins keep cycling the fleet mix, same
                        // rule as the simulator's on_instance_ready
                        let profile = self.decode_profile(id);
                        let raw_cap = (exp.cluster.kv_capacity_tokens as f64 * profile.mem_mult)
                            .round() as u64;
                        let added = state.add_instance(raw_cap);
                        debug_assert_eq!(added, id, "state and thread pools must align");
                        state.set_capacity(id, round_cap(raw_cap));
                        state.set_profile(id, profile);
                        let (st, handle) = self.spawn_decode_thread(id, pred_kind, &ev_tx);
                        handles.push(handle);
                        instances.push(st);
                    }
                    PoolRole::Prefill => {
                        prefill_provisioning -= 1;
                        let (worker, handle) = self.spawn_prefill_worker(
                            next_prefill_seed,
                            Arc::clone(&pf_in_rx),
                            pf_out_tx.clone(),
                        );
                        next_prefill_seed += 1;
                        handles.push(handle);
                        prefill_workers.push(worker);
                    }
                }
            }

            // re-dispatch parked payloads whose time has come: rejected
            // admissions, OOM recompute victims, and migrated-out requests
            // after their modeled KV-transfer delay (paper §5.4)
            while let Some((not_before, _)) = retries.front() {
                if *not_before > now_i {
                    break;
                }
                let (_, payload) = retries
                    .pop_front()
                    .expect("front checked non-empty above");
                migrating.retain(|&id| id != payload.id);
                state.set_migrating(payload.id, false);
                let di = if let Some((dst, amt)) = reservations.remove(&payload.id) {
                    // migration delivery: go to the decided target and
                    // release the exact reservation
                    state.release_inbound(dst, amt);
                    dst
                } else {
                    // rejected admission / OOM recompute: re-dispatch
                    let tokens = payload.pos as u64 + payload.replay.len() as u64;
                    // never-admissible guard (mirrors the simulator's
                    // stranded-request fix): a payload whose KV cannot
                    // pass the admission watermark on ANY instance would
                    // bounce through this retry queue forever
                    let admissible = (0..state.n_instances()).any(|i| {
                        tokens.max(1) <= admission_watermark(state.stats(i).kv_capacity_tokens())
                    });
                    if !admissible {
                        match trackers.get_mut(&payload.id) {
                            Some(t) if !t.done => {
                                t.done = true;
                                failed += 1;
                                obs_registry.inc("requests.failed", 1);
                            }
                            _ => {}
                        }
                        eprintln!(
                            "[serve] request {} ({tokens} KV tokens) can never pass the \
                             admission watermark: failed terminally",
                            payload.id
                        );
                        continue;
                    }
                    control.set_decision_time(now_s);
                    let t0 = Instant::now();
                    let di = control.dispatch(
                        &state.view(),
                        &IncomingRequest {
                            id: payload.id,
                            tokens,
                            predicted_remaining: payload.predicted_remaining,
                            preferred_instance: None,
                        },
                    );
                    control
                        .attribution_mut()
                        .note_last_cost_us(t0.elapsed().as_micros() as u64);
                    di
                };
                let _ = instances[di].cmd.send(DecodeCommand::Admit(payload));
            }

            // prefill completions (non-blocking)
            while let Ok(msg) = pf_out_rx.try_recv() {
                match msg {
                    PrefillMsg::Err {
                        id,
                        prompt_tokens,
                        msg,
                    } => {
                        eprintln!("[serve] prefill failed for {id}: {msg}");
                        failed += 1;
                        obs_registry.inc("requests.failed", 1);
                        trackers
                            .get_mut(&id)
                            .expect("prefill error for untracked request")
                            .done = true;
                        prefill_inflight_reqs = prefill_inflight_reqs.saturating_sub(1);
                        prefill_inflight_tokens =
                            prefill_inflight_tokens.saturating_sub(prompt_tokens);
                    }
                    PrefillMsg::Done {
                        req,
                        kv,
                        hidden,
                        first_token,
                        at,
                    } => {
                        prefill_inflight_reqs = prefill_inflight_reqs.saturating_sub(1);
                        prefill_inflight_tokens =
                            prefill_inflight_tokens.saturating_sub(req.prompt.len() as u64);
                        rates.on_prefill_done(req.prompt.len() as u64);
                        let t = trackers
                            .get_mut(&req.id)
                            .expect("prefill done for untracked request");
                        t.latency.prefill_done = Some(since(at));
                        t.latency.first_token = Some(since(at));
                        t.last_token = Some(at);
                        recorder.record(
                            since(at),
                            TraceEvent::PrefillDone {
                                request: req.id,
                                instance: 0,
                            },
                        );
                        // initial prediction (drives PredictedLoad dispatch
                        // and seeds the rescheduler's view). Live estimates
                        // are points (σ = 0): quantiles degrade to the mean.
                        let pred = match pred_kind {
                            PredictorKind::None => None,
                            PredictorKind::LlmNative | PredictorKind::Debiased => self
                                .runtime
                                .predict_remaining(&hidden)
                                .ok()
                                .map(|v| Prediction::new(v[0] as f64, 0.0, 0)),
                            PredictorKind::Oracle | PredictorKind::Binned(_) => {
                                req.forced_output.map(|o| Prediction::exact(o as f64))
                            }
                        };
                        if let Some(p) = pred {
                            let t = trackers.get_mut(&req.id).expect("tracker exists");
                            t.pred_log.push(PredSample {
                                generated: 0,
                                predicted: p.mean,
                            });
                            t.last_pred_iter = Some(p.issued_at_iter);
                        }
                        // prefix-cache consultation: a follow-up turn whose
                        // previous turn left its KV cached prefers the
                        // holding instance (cursor index >= 1 marks a
                        // follow-up; index 0 is a session's first turn).
                        let mut preferred = None;
                        let mut cache_hit: Option<(InstanceId, u64)> = None;
                        let mut cache_consulted = false;
                        if prefix_cache.enabled() {
                            if let Some(&(s, k)) = session.cursor.get(&req.id) {
                                if k >= 1 {
                                    cache_consulted = true;
                                    match prefix_cache.take(s, since(at)) {
                                        Some(e)
                                            if instances
                                                .get(e.instance)
                                                .map(|i| i.lifecycle == Lifecycle::Active)
                                                .unwrap_or(false) =>
                                        {
                                            preferred = Some(e.instance);
                                            cache_hit = Some((e.instance, e.tokens));
                                        }
                                        Some(_) => {
                                            // holder drained/retired between
                                            // turns: entry is unusable
                                            prefix_cache.note_evicted();
                                            prefix_cache.note_miss();
                                        }
                                        None => prefix_cache.note_miss(),
                                    }
                                    // take removes expired entries even when
                                    // it returns None: resync the mirror
                                    sync_cached_mirror(&mut state, &prefix_cache);
                                }
                            }
                        }
                        control.set_decision_time(now_s);
                        let t0 = Instant::now();
                        let di = control.dispatch(
                            &state.view(),
                            &IncomingRequest {
                                id: req.id,
                                tokens: req.prompt.len() as u64,
                                predicted_remaining: pred,
                                preferred_instance: preferred,
                            },
                        );
                        control
                            .attribution_mut()
                            .note_last_cost_us(t0.elapsed().as_micros() as u64);
                        if cache_consulted {
                            let hit = cache_hit.map_or(false, |(h, _)| di == h);
                            control
                                .attribution_mut()
                                .record_cache(&exp.kvcache.policy, req.id, hit);
                        }
                        if let Some((holder, cached)) = cache_hit {
                            let prompt = req.prompt.len() as u64;
                            if di == holder {
                                // at least one token must be prefilled to
                                // produce this turn's first logits
                                let reused = cached.min(prompt.saturating_sub(1));
                                prefix_cache.note_hit(reused);
                                if let Some(t) = trackers.get_mut(&req.id) {
                                    t.latency.suffix_tokens = (prompt - reused) as u32;
                                }
                            } else {
                                // routed away from the holder: the live path
                                // always recomputes (no cross-instance KV
                                // move on the serving substrate)
                                prefix_cache.note_miss();
                                prefix_cache.note_recompute();
                            }
                        }
                        let payload = Box::new(AdmitPayload {
                            id: req.id,
                            kv,
                            pos: req.prompt.len() as i32,
                            next_token: first_token,
                            generated: 0,
                            forced_remaining: req.forced_output,
                            replay: Default::default(),
                            predicted_remaining: pred,
                        });
                        let _ = instances[di].cmd.send(DecodeCommand::Admit(payload));
                    }
                }
            }

            // decode events (block briefly so the loop doesn't spin)
            match ev_rx.recv_timeout(Duration::from_millis(2)) {
                Err(RecvTimeoutError::Disconnected) => break,
                Err(RecvTimeoutError::Timeout) => {}
                Ok(first) => {
                    let mut pending = Some(first);
                    while let Some(ev) = pending.take() {
                        self.handle_event(
                            ev,
                            &since,
                            &mut trackers,
                            &mut instances,
                            &mut state,
                            &mut migrating,
                            &mut reservations,
                            &mut recorder,
                            &mut retries,
                            &mut completed,
                            &mut oom_events,
                            &mut output_mean,
                            &mut scorecard,
                            &mut session,
                            &mut prefix_cache,
                            &mut obs_registry,
                        );
                        pending = ev_rx.try_recv().ok();
                    }
                }
            }

            // scheduler tick (Algorithm 1)
            if last_tick.elapsed() >= interval {
                last_tick = Instant::now();
                let now_s = start.elapsed().as_secs_f64();
                if prefix_cache.enabled() {
                    // TTL housekeeping rides the scheduler tick (same
                    // cadence as the simulator's)
                    prefix_cache.expire(now_s);
                    sync_cached_mirror(&mut state, &prefix_cache);
                }
                // retired slots are out of the pool: they must not
                // deflate the cross-instance variance metrics
                let iters: Vec<f64> = (0..instances.len())
                    .filter(|&i| instances[i].lifecycle != Lifecycle::Retired)
                    .map(|i| {
                        let s = state.stats(i);
                        if s.batch_size() == 0 {
                            0.0
                        } else {
                            s.ewma_iter_ms()
                        }
                    })
                    .collect();
                exec_var.snapshot(now_s, &iters);
                let loads: Vec<f64> = instances
                    .iter()
                    .filter(|s| s.lifecycle != Lifecycle::Retired)
                    .map(|s| s.kv_used as f64)
                    .collect();
                load_var.snapshot(now_s, &loads);
                for (i, st) in instances.iter().enumerate() {
                    if st.lifecycle == Lifecycle::Retired {
                        continue;
                    }
                    recorder.record(
                        now_s,
                        TraceEvent::KvSample {
                            instance: i,
                            kv_frac: st.kv_used as f64 / st.kv_capacity.max(1) as f64,
                            tokens: st.kv_used,
                            batch: state.stats(i).batch_size(),
                        },
                    );
                }
                if control.rescheduling_enabled() {
                    control.observe_avg_iter_s(state.avg_iter_s());
                    if output_mean.count() > 10 {
                        control.observe_default_remaining(output_mean.mean() / 2.0);
                    }
                    control.set_decision_time(now_s);
                    let t0 = Instant::now();
                    let decisions = control.reschedule(&state.view());
                    control
                        .attribution_mut()
                        .note_last_cost_us(t0.elapsed().as_micros() as u64);
                    for d in decisions {
                        migrations += 1;
                        obs_registry.inc("migrations", 1);
                        migrating.push(d.request);
                        state.set_migrating(d.request, true);
                        state.reserve_inbound(d.dst, d.kv_tokens);
                        reservations.insert(d.request, (d.dst, d.kv_tokens));
                        recorder.record(
                            now_s,
                            TraceEvent::Migration {
                                request: d.request,
                                src: d.src,
                                dst: d.dst,
                                kv_tokens: d.kv_tokens,
                            },
                        );
                        let _ = instances[d.src]
                            .cmd
                            .send(DecodeCommand::MigrateOut { id: d.request });
                    }
                }
            }

            // elastic scale tick: rates, drains, pool sample, decisions
            if last_scale.elapsed() >= scale_interval {
                let dt = last_scale.elapsed().as_secs_f64();
                last_scale = Instant::now();
                let now_s = start.elapsed().as_secs_f64();
                prefill_drains.retain(|&t| t > Instant::now());
                let prefill_active = prefill_workers.iter().filter(|w| w.is_active()).count();
                rates.tick(dt, prefill_active);

                // keep drains moving: migrate residents of draining
                // instances toward active headroom, and retire instances
                // whose drain has completed (reports show them empty and
                // nothing is reserved toward them)
                for di in 0..instances.len() {
                    if instances[di].lifecycle != Lifecycle::Draining {
                        continue;
                    }
                    let residents: Vec<RequestView> = state.active(di).to_vec();
                    for r in residents {
                        if r.migrating {
                            continue;
                        }
                        let dst = crate::coordinator::elastic::drain_destination(
                            &state.view(),
                            r.tokens,
                            exp.cluster.max_batch,
                        );
                        if let Some(dst) = dst {
                            migrations += 1;
                            obs_registry.inc("migrations", 1);
                            migrating.push(r.id);
                            state.set_migrating(r.id, true);
                            state.reserve_inbound(dst, r.tokens);
                            reservations.insert(r.id, (dst, r.tokens));
                            recorder.record(
                                now_s,
                                TraceEvent::Migration {
                                    request: r.id,
                                    src: di,
                                    dst,
                                    kv_tokens: r.tokens,
                                },
                            );
                            let _ = instances[di]
                                .cmd
                                .send(DecodeCommand::MigrateOut { id: r.id });
                        }
                    }
                    let empty = state.stats(di).batch_size() == 0
                        && state.stats(di).inbound_reserved_tokens() == 0
                        && !reservations.values().any(|&(dst, _)| dst == di);
                    if empty {
                        // retire the slot for scheduling purposes but keep
                        // the thread alive in Drain mode until the final
                        // shutdown: a racing Admit that was accepted before
                        // the Drain command (and not yet reflected in any
                        // Report) would otherwise be lost with the thread.
                        // The bounce path returns every later payload, and
                        // an idle thread costs only its 20 ms poll.
                        instances[di].lifecycle = Lifecycle::Retired;
                        state.set_lifecycle(di, Lifecycle::Retired);
                        if instances[di].flip_to_prefill {
                            instances[di].flip_to_prefill = false;
                            prefill_provisioning += 1;
                            let at = ready_after(elastic.flip_delay_s);
                            pending_ready.push((at, PoolRole::Prefill));
                        }
                    }
                }

                let pool = PoolStats {
                    now: now_s,
                    prefill_active,
                    prefill_draining: prefill_drains.len(),
                    prefill_provisioning,
                    decode_active: instances
                        .iter()
                        .filter(|i| i.lifecycle == Lifecycle::Active)
                        .count(),
                    decode_draining: instances
                        .iter()
                        .filter(|i| i.lifecycle == Lifecycle::Draining)
                        .count(),
                    decode_provisioning,
                    prefill_queued_reqs: prefill_inflight_reqs,
                    prefill_queued_tokens: prefill_inflight_tokens,
                    arrival_tokens_per_s: rates.arrival_tokens_per_s(),
                    prefill_tokens_per_s: rates.prefill_tokens_per_s(),
                };
                pool_timeline.push(PoolSample {
                    t: now_s,
                    prefill_active: pool.prefill_active,
                    decode_active: pool.decode_active,
                    draining: pool.prefill_draining + pool.decode_draining,
                    provisioning: pool.prefill_provisioning + pool.decode_provisioning,
                });
                control.set_decision_time(now_s);
                let t0 = Instant::now();
                let actions = control.scale(&state.view(), &pool);
                control
                    .attribution_mut()
                    .note_last_cost_us(t0.elapsed().as_micros() as u64);
                for action in actions {
                    scale_log.push(ScaleRecord { t: now_s, action });
                    match action {
                        ScalingAction::FlipToDecode
                        | ScalingAction::Retire {
                            role: PoolRole::Prefill,
                        } => {
                            // drain the most recently added active worker
                            // (workers share one queue, so any choice is
                            // load-equivalent). Unlike the sim, the live
                            // flip warm-up starts now and may overlap the
                            // worker's final request — the pool can
                            // transiently exceed the nominal budget by one
                            // while the worker finishes.
                            if let Some(w) = prefill_workers.iter().rev().find(|w| w.is_active()) {
                                w.stop.store(true, Ordering::Relaxed);
                                prefill_drains.push(Instant::now() + scale_interval);
                                if action == ScalingAction::FlipToDecode {
                                    decode_provisioning += 1;
                                    let at = ready_after(elastic.flip_delay_s);
                                    pending_ready.push((at, PoolRole::Decode));
                                }
                            }
                        }
                        ScalingAction::FlipToPrefill { decode } => {
                            if instances[decode].lifecycle == Lifecycle::Active {
                                instances[decode].lifecycle = Lifecycle::Draining;
                                instances[decode].flip_to_prefill = true;
                                state.set_lifecycle(decode, Lifecycle::Draining);
                                // drain-then-flip invariant: a draining
                                // instance flushes its cached prefixes
                                if prefix_cache.enabled() {
                                    prefix_cache.evict_instance(decode);
                                    sync_cached_mirror(&mut state, &prefix_cache);
                                }
                                let _ = instances[decode].cmd.send(DecodeCommand::Drain);
                            }
                        }
                        ScalingAction::Retire {
                            role: PoolRole::Decode,
                        } => {
                            let target =
                                crate::coordinator::elastic::emptiest_active_decode(&state.view());
                            if let Some(di) = target {
                                instances[di].lifecycle = Lifecycle::Draining;
                                instances[di].flip_to_prefill = false;
                                state.set_lifecycle(di, Lifecycle::Draining);
                                if prefix_cache.enabled() {
                                    prefix_cache.evict_instance(di);
                                    sync_cached_mirror(&mut state, &prefix_cache);
                                }
                                let _ = instances[di].cmd.send(DecodeCommand::Drain);
                            }
                        }
                        ScalingAction::Provision { role } => {
                            match role {
                                PoolRole::Prefill => prefill_provisioning += 1,
                                PoolRole::Decode => decode_provisioning += 1,
                            }
                            pending_ready.push((ready_after(elastic.provision_delay_s), role));
                        }
                    }
                }
            }

        }

        // shutdown
        for st in &instances {
            let _ = st.cmd.send(DecodeCommand::Shutdown);
        }
        drop(pf_in_tx);
        for h in handles {
            let _ = h.join();
        }

        let wall = start.elapsed().as_secs_f64();
        // final end-state series point, then assemble the obs report
        // (spans need the recorder rows before it moves into the outcome)
        if obs_registry.enabled() {
            let active = instances
                .iter()
                .filter(|i| i.lifecycle == Lifecycle::Active)
                .count();
            obs_registry.set_gauge("decode.active_instances", active as f64);
            obs_registry.sample(wall);
        }
        let obs = crate::obs::assemble_report(
            exp.obs.enabled,
            exp.cluster.seed,
            exp.obs.sample_rate,
            exp.obs.ring_capacity,
            recorder.rows(),
            obs_registry,
            control.take_attribution(),
        );
        let mut metrics = RunMetrics {
            completed: Vec::new(),
            duration: wall,
            oom_events,
            migrations,
        };
        for t in trackers.into_values() {
            if t.latency.finished.is_some() {
                metrics.completed.push(t.latency);
            }
        }
        Ok(ServeOutcome {
            metrics,
            exec_var,
            load_var,
            recorder,
            scheduler_stats: control.stats(),
            wall_s: wall,
            oom_events,
            migrations,
            pool_timeline,
            scale_actions: scale_log,
            scorecard,
            cache: prefix_cache.report(),
            reliability: ReliabilityReport::default(),
            obs,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_event(
        &self,
        ev: DecodeEvent,
        since: &dyn Fn(Instant) -> Time,
        trackers: &mut BTreeMap<RequestId, ReqTracker>,
        instances: &mut [InstanceState],
        state: &mut ClusterState,
        migrating: &mut Vec<RequestId>,
        reservations: &mut BTreeMap<RequestId, (InstanceId, u64)>,
        recorder: &mut TraceRecorder,
        retries: &mut VecDeque<(Instant, Box<AdmitPayload>)>,
        completed: &mut usize,
        oom_events: &mut u64,
        output_mean: &mut RunningVariance,
        scorecard: &mut Scorecard,
        session: &mut SessionRt,
        prefix_cache: &mut PrefixCache,
        obs: &mut MetricsRegistry,
    ) {
        match ev {
            DecodeEvent::Token { id, at, .. } => {
                if let Some(t) = trackers.get_mut(&id) {
                    if let Some(prev) = t.last_token {
                        let gap = at.duration_since(prev).as_secs_f64();
                        t.tpot_sum += gap;
                        t.tpot_max = t.tpot_max.max(gap);
                    }
                    t.last_token = Some(at);
                    t.generated += 1;
                    if t.latency.first_token.is_none() {
                        t.latency.first_token = Some(since(at));
                    }
                }
            }
            DecodeEvent::Finished {
                instance,
                id,
                generated,
                at,
            } => {
                // a migration decided for a request that finished before
                // the MigrateOut command reached its slot is silently
                // dropped by the instance ("stale decision"): release the
                // reservation here or it leaks for the rest of the run
                if let Some((dst, amt)) = reservations.remove(&id) {
                    state.release_inbound(dst, amt);
                    migrating.retain(|&m| m != id);
                }
                let mut finished_now = false;
                let mut done_prompt_tokens = 0u32;
                if let Some(t) = trackers.get_mut(&id) {
                    if !t.done {
                        t.done = true;
                        finished_now = true;
                        done_prompt_tokens = t.latency.prompt_tokens;
                        *completed += 1;
                        output_mean.push(generated as f64);
                        t.latency.finished = Some(since(at));
                        t.latency.output_tokens = generated;
                        t.latency.finalize_tpot(t.generated, t.tpot_sum, t.tpot_max);
                        // completion is when every logged estimate gains a
                        // ground truth: fold into the calibration scorecard
                        let log = std::mem::take(&mut t.pred_log);
                        scorecard.observe_completion(generated, &log);
                        obs.inc("requests.finished", 1);
                        if let Some(ft) = t.latency.first_token {
                            obs.observe("ttft_s", ft - t.latency.arrival);
                        }
                        if t.generated > 1 {
                            obs.observe("tpot_s", t.tpot_sum / (t.generated - 1) as f64);
                        }
                        recorder.record(
                            since(at),
                            TraceEvent::Finished {
                                request: id,
                                instance,
                            },
                        );
                    }
                }
                // spawn the session's next turn: it arrives a think-time
                // after THIS completion, prompt carrying the accumulated
                // history (same schedule the simulator realizes)
                if finished_now {
                    let cursor = session.cursor.get(&id).copied();
                    if let Some((s, k)) = cursor {
                        let turn = session
                            .plan
                            .scripts
                            .get(s as usize)
                            .and_then(|sc| sc.get(k as usize))
                            .cloned();
                        if let Some(turn) = turn {
                            let nid = session.next_id;
                            session.next_id += 1;
                            let arrival = since(at) + turn.think_time_s;
                            let lr = LiveRequest::for_session_turn(
                                nid,
                                arrival,
                                &turn,
                                self.runtime.meta.max_prompt,
                            );
                            trackers.insert(
                                nid,
                                ReqTracker {
                                    latency: RequestLatency {
                                        id: nid,
                                        class: turn.class,
                                        arrival,
                                        prompt_tokens: lr.prompt.len() as u32,
                                        suffix_tokens: lr.prompt.len() as u32,
                                        ..Default::default()
                                    },
                                    last_token: None,
                                    tpot_sum: 0.0,
                                    tpot_max: 0.0,
                                    generated: 0,
                                    done: false,
                                    pred_log: Vec::new(),
                                    last_pred_iter: None,
                                },
                            );
                            session.cursor.insert(nid, (s, k + 1));
                            session.queue.push((arrival, lr));
                            session.spawned += 1;
                            obs.inc("session.follow_ups", 1);
                            // retain the completed turn's KV for the
                            // follow-up we just scheduled. Hard cap is the
                            // instance's physical headroom for idle bytes:
                            // capacity minus active KV minus inbound
                            // reservations — live requests always win.
                            if prefix_cache.enabled()
                                && instances[instance].lifecycle == Lifecycle::Active
                            {
                                let kept = done_prompt_tokens as u64 + generated as u64;
                                let stats = state.stats(instance);
                                let hard_cap = stats
                                    .kv_capacity_tokens()
                                    .saturating_sub(instances[instance].kv_used)
                                    .saturating_sub(stats.inbound_reserved_tokens());
                                prefix_cache.insert(
                                    s,
                                    instance,
                                    kept,
                                    since(at),
                                    Some(Prediction::exact(turn.think_time_s)),
                                    hard_cap,
                                );
                                // insert may supersede or evict internally
                                // even when it refuses: always resync
                                sync_cached_mirror(state, prefix_cache);
                            }
                        }
                    }
                }
            }
            DecodeEvent::AdmitRejected { payload, .. } => {
                retries.push_back((Instant::now() + std::time::Duration::from_millis(25), payload));
            }
            DecodeEvent::MigratedOut { payload, .. } => {
                // transfer delay: park in the retry queue; the retry path
                // re-dispatches onto the (stale-aware) freshest snapshot,
                // which for a migration is the chosen dst — the reschedule
                // decision already reserved capacity there.
                let delay = self
                    .params
                    .migration
                    .transfer_time(payload.pos as u64);
                if let Some(t) = trackers.get_mut(&payload.id) {
                    t.latency.migrations += 1;
                }
                retries.push_back((
                    Instant::now() + std::time::Duration::from_secs_f64(delay),
                    payload,
                ));
            }
            DecodeEvent::Oom { instance, victims, at } => {
                *oom_events += 1;
                obs.inc("oom.events", 1);
                obs.inc("oom.victims", victims.len() as u64);
                obs.inc("recompute.queued", victims.len() as u64);
                recorder.record(
                    since(at),
                    TraceEvent::Oom {
                        instance,
                        victims: victims.len(),
                    },
                );
                for v in victims {
                    if let Some(t) = trackers.get_mut(&v.id) {
                        t.latency.hit_oom = true;
                    }
                    retries.push_back((Instant::now(), v));
                }
            }
            DecodeEvent::Report {
                instance,
                slots,
                ewma_iter_ms,
                kv_used,
                kv_capacity,
                at,
            } => {
                // authoritative per-instance reconciliation: the decode
                // thread owns the truth; fold its report into the shared
                // scheduler state (O(slots of this instance), not
                // O(cluster))
                let views = slots
                    .iter()
                    .map(|s| RequestView {
                        id: s.id,
                        tokens: s.tokens,
                        predicted_remaining: s.predicted_remaining,
                        migrating: migrating.contains(&s.id),
                    })
                    .collect();
                // reports are also where repredictions surface: log each
                // changed estimate for the completion-time scorecard fold.
                // The sample's progress point is the estimate's ISSUE time
                // (`issued_at_iter`, stamped by the instance thread) — the
                // tracker's current token count may already be past it,
                // which would charge the predictor for tokens generated
                // after it spoke (an exact oracle would score a fake bias).
                for s in &slots {
                    let Some(p) = s.predicted_remaining else {
                        continue;
                    };
                    if let Some(t) = trackers.get_mut(&s.id) {
                        let fresh =
                            t.last_pred_iter.map_or(true, |prev| p.issued_at_iter > prev);
                        if fresh && !t.done {
                            t.pred_log.push(PredSample {
                                generated: p.issued_at_iter as u32,
                                predicted: p.mean,
                            });
                            t.last_pred_iter = Some(p.issued_at_iter);
                        }
                    }
                }
                state.sync_instance(instance, views);
                state.set_iter_ewma(instance, ewma_iter_ms);
                state.set_capacity(instance, kv_capacity);
                let st = &mut instances[instance];
                st.kv_used = kv_used;
                st.kv_capacity = kv_capacity;
                // batch growth encroaching on idle cached bytes: evict
                // cold prefixes until the authoritative report plus the
                // cache fit the instance again (live requests always win)
                if prefix_cache.enabled() {
                    let total = kv_used + prefix_cache.cached_on(instance);
                    if total > kv_capacity {
                        prefix_cache.evict_for_headroom(
                            instance,
                            total - kv_capacity,
                            since(at),
                        );
                        sync_cached_mirror(state, prefix_cache);
                    }
                }
            }
        }
    }
}
