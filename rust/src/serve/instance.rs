//! Decode instance thread: continuous batching over the PJRT runtime.
//!
//! Each instance owns a fixed-bucket KV device buffer (host-mirrored),
//! a paged [`KvCacheManager`] enforcing its token capacity, and a slot
//! table. It consumes [`DecodeCommand`]s from the coordinator and emits
//! [`DecodeEvent`]s (tokens, completions, OOMs, migration payloads, and
//! per-step state reports used by Algorithm 1).

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::PredictorKind;
use crate::coordinator::admission_watermark;
use crate::kvcache::KvCacheManager;
use crate::predictor::{Prediction, Repredictor};
use crate::prng::Pcg64;
use crate::runtime::{HostTensor, StarRuntime};
use crate::{InstanceId, RequestId};

/// Commands from the coordinator to one decode instance.
pub enum DecodeCommand {
    /// Admit a request whose KV arrives from prefill or migration.
    Admit(Box<AdmitPayload>),
    /// Begin migrating a request out: pause it, extract its KV slot, and
    /// reply with [`DecodeEvent::MigratedOut`].
    MigrateOut { id: RequestId },
    /// Elastic drain: stop accepting admissions (every further `Admit`
    /// bounces back as [`DecodeEvent::AdmitRejected`], so a payload that
    /// races a drain decision is returned, never lost); residents keep
    /// decoding until they finish or migrate out.
    Drain,
    Shutdown,
}

/// Everything needed to (re)start decoding a request on an instance.
pub struct AdmitPayload {
    pub id: RequestId,
    /// KV slice [L,2,1,H,S,Dh]; zeroed for OOM-recompute replays.
    pub kv: HostTensor,
    /// Current sequence length (position where the next token is written).
    pub pos: i32,
    /// Next token to feed.
    pub next_token: i32,
    pub generated: u32,
    /// Remaining output budget for trace-forced runs (None = run to EOS).
    pub forced_remaining: Option<u32>,
    /// Tokens to replay through decode before resuming emission
    /// (OOM recompute path: rebuilds the KV cache).
    pub replay: VecDeque<u8>,
    pub predicted_remaining: Option<Prediction>,
}

/// Events from a decode instance to the coordinator.
pub enum DecodeEvent {
    /// One output token emitted for a request (proxy stream content).
    Token {
        instance: InstanceId,
        id: RequestId,
        at: Instant,
        byte: u8,
    },
    Finished {
        instance: InstanceId,
        id: RequestId,
        generated: u32,
        at: Instant,
    },
    /// Admission failed (capacity race): payload returned to coordinator.
    AdmitRejected {
        instance: InstanceId,
        payload: Box<AdmitPayload>,
    },
    /// Migration payload extracted; the slot is freed.
    MigratedOut {
        instance: InstanceId,
        payload: Box<AdmitPayload>,
    },
    /// OOM: victims evicted; each must recompute via replay elsewhere.
    Oom {
        instance: InstanceId,
        victims: Vec<Box<AdmitPayload>>,
        at: Instant,
    },
    /// Post-step state report (Algorithm 1's worker report input).
    Report {
        instance: InstanceId,
        slots: Vec<SlotSnapshot>,
        ewma_iter_ms: f64,
        kv_used: u64,
        kv_capacity: u64,
        at: Instant,
    },
}

/// Scheduler-visible slot state.
#[derive(Clone, Debug)]
pub struct SlotSnapshot {
    pub id: RequestId,
    pub tokens: u64,
    pub predicted_remaining: Option<Prediction>,
}

struct Slot {
    id: RequestId,
    pos: i32,
    next_token: i32,
    generated: u32,
    forced_remaining: Option<u32>,
    replay: VecDeque<u8>,
    token_history: Vec<u8>,
    predicted_remaining: Option<Prediction>,
    iters_since_predict: u32,
}

/// Configuration for one decode instance thread.
pub struct DecodeInstance {
    pub id: InstanceId,
    pub runtime: Arc<StarRuntime>,
    pub kv_capacity_tokens: u64,
    pub block_tokens: u32,
    pub max_batch: usize,
    pub predictor: PredictorKind,
    pub predict_every_iters: u32,
    pub temperature: f32,
    pub seed: u64,
}

impl DecodeInstance {
    /// Run the instance loop until `Shutdown`. Blocking; call on its own
    /// thread.
    pub fn run(self, commands: Receiver<DecodeCommand>, events: Sender<DecodeEvent>) {
        let bucket = *self
            .runtime
            .meta
            .decode_buckets
            .last()
            .expect("decode buckets");
        let max_batch = self.max_batch.min(bucket);
        let mut kv_buf = self.runtime.new_kv_buffer(bucket);
        let mut kv_mgr = KvCacheManager::new(self.kv_capacity_tokens, self.block_tokens);
        let mut slots: Vec<Option<Slot>> = (0..bucket).map(|_| None).collect();
        let mut rng = Pcg64::new(self.seed, (self.id as u64) ^ 0xDEC0DE);
        // the SAME reprediction schedule the simulator runs
        // (predictor::Repredictor — one due-slot scan, one cost model)
        let repred = Repredictor::new(self.predict_every_iters);
        let mut ewma_iter_ms = 0.0f64;
        let mut any_steps = false;
        let mut draining = false;
        let mut was_busy = false;

        'outer: loop {
            // 1. drain control traffic
            loop {
                let cmd = if slots.iter().all(Option::is_none) {
                    // idle: block (with timeout so shutdown is prompt)
                    match commands.recv_timeout(Duration::from_millis(20)) {
                        Ok(c) => c,
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                        Err(_) => break 'outer,
                    }
                } else {
                    match commands.try_recv() {
                        Ok(c) => c,
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => break 'outer,
                    }
                };
                match cmd {
                    DecodeCommand::Shutdown => break 'outer,
                    DecodeCommand::Drain => draining = true,
                    DecodeCommand::Admit(p) => {
                        if draining {
                            // drains accept no admissions; give the
                            // payload back instead of dropping it
                            let _ = events.send(DecodeEvent::AdmitRejected {
                                instance: self.id,
                                payload: p,
                            });
                        } else {
                            self.admit(
                                *p,
                                &mut slots,
                                &mut kv_buf,
                                &mut kv_mgr,
                                bucket,
                                max_batch,
                                &events,
                            );
                        }
                    }
                    DecodeCommand::MigrateOut { id } => {
                        self.migrate_out(id, &mut slots, &mut kv_buf, &mut kv_mgr, bucket, &events);
                    }
                }
            }

            if slots.iter().all(Option::is_none) {
                // falling idle must be *reported*: the coordinator's view
                // of this instance would otherwise keep the last busy
                // report's slots forever (it only reconciles on Report),
                // which both skews dispatch and stalls elastic drains.
                if was_busy {
                    was_busy = false;
                    let _ = events.send(DecodeEvent::Report {
                        instance: self.id,
                        slots: Vec::new(),
                        ewma_iter_ms,
                        kv_used: kv_mgr.used_tokens(),
                        kv_capacity: kv_mgr.capacity_tokens(),
                        at: Instant::now(),
                    });
                }
                continue;
            }
            was_busy = true;

            // 2. one batched decode iteration
            let t0 = Instant::now();
            let mut tokens = vec![1i32; bucket];
            let mut pos = vec![0i32; bucket];
            for (i, s) in slots.iter().enumerate() {
                if let Some(s) = s {
                    tokens[i] = s.next_token;
                    pos[i] = s.pos;
                }
            }
            let out = match self.runtime.decode_step(bucket, &tokens, &pos, &kv_buf) {
                Ok(o) => o,
                Err(e) => {
                    eprintln!("[instance {}] decode error: {e}", self.id);
                    break;
                }
            };
            kv_buf = out.kv;
            let now = Instant::now();

            // 3. per-slot bookkeeping
            let vocab = self.runtime.meta.vocab;
            let d = self.runtime.meta.d_model;
            let max_seq = self.runtime.meta.max_seq as i32;
            let mut finished: Vec<usize> = Vec::new();
            let mut oom_victims: Vec<Box<AdmitPayload>> = Vec::new();

            for i in 0..bucket {
                let Some(slot) = slots[i].as_mut() else {
                    continue;
                };
                // KV grew by one token
                if kv_mgr.append_token(slot.id, self.id).is_err() {
                    // OOM: evict the largest slots until the append fits
                    let victim_ids = kv_mgr.eviction_victims(1);
                    for vid in victim_ids {
                        if let Some(vi) =
                            (0..bucket).find(|&j| slots[j].as_ref().map(|s| s.id) == Some(vid))
                        {
                            kv_mgr.release(vid);
                            let v = slots[vi]
                                .take()
                                .expect("victim slot located by id scan above");
                            oom_victims.push(Box::new(AdmitPayload {
                                id: v.id,
                                kv: self.runtime.new_kv_buffer(1),
                                pos: 0,
                                next_token: 0,
                                generated: v.generated,
                                forced_remaining: v.forced_remaining,
                                replay: v.token_history.clone().into(),
                                predicted_remaining: v.predicted_remaining,
                            }));
                        }
                    }
                    if slots[i].is_none() {
                        continue; // this very slot was the victim
                    }
                    let slot = slots[i].as_mut().expect("slot checked occupied above");
                    kv_mgr
                        .append_token(slot.id, self.id)
                        .expect("append after eviction");
                }
                let slot = slots[i]
                    .as_mut()
                    .expect("slot survives eviction handling above");
                slot.pos += 1;
                slot.token_history.push(slot.next_token as u8);

                if let Some(rb) = slot.replay.pop_front() {
                    // recompute mode: feed history, no emission
                    slot.next_token = rb as i32;
                    continue;
                }

                // sample next token
                let logits = &out.logits[i * vocab..(i + 1) * vocab];
                let sampled = super::sample_token(logits, self.temperature, &mut rng) as i32;
                slot.generated += 1;
                slot.iters_since_predict += 1;
                let byte = slot.next_token as u8; // the token just processed
                let _ = events.send(DecodeEvent::Token {
                    instance: self.id,
                    id: slot.id,
                    at: now,
                    byte,
                });

                let done_forced = slot
                    .forced_remaining
                    .map(|r| slot.generated >= r)
                    .unwrap_or(false);
                let done_eos = slot.forced_remaining.is_none()
                    && sampled == self.runtime.meta.eos as i32;
                let done_cap = slot.pos >= max_seq - 1
                    || slot.generated >= self.runtime.meta.max_output as u32;
                if done_forced || done_eos || done_cap {
                    finished.push(i);
                } else {
                    slot.next_token = sampled;
                }
            }

            // 4. reprediction: the shared batched due-slot scan (§5.3),
            // identical to the simulator's (predictor::Repredictor)
            let predict_slots: Vec<usize> = if self.predictor.uses_prediction() {
                repred.due_slots((0..bucket).filter_map(|i| {
                    let s = slots[i].as_ref()?;
                    // finished slots leave this step; replaying slots have
                    // not resumed emission yet
                    if finished.contains(&i) || !s.replay.is_empty() {
                        return None;
                    }
                    Some((i, s.iters_since_predict))
                }))
            } else {
                Vec::new()
            };
            if !predict_slots.is_empty() {
                match self.predictor {
                    // the live `debiased` selection runs the MLP estimate
                    // uncorrected (online debiasing is simulator-side)
                    PredictorKind::LlmNative | PredictorKind::Debiased => {
                        let mut h = Vec::with_capacity(predict_slots.len() * d);
                        for &i in &predict_slots {
                            h.extend_from_slice(&out.hidden[i * d..(i + 1) * d]);
                        }
                        if let Ok(preds) = self.runtime.predict_remaining(&h) {
                            for (k, &i) in predict_slots.iter().enumerate() {
                                if let Some(s) = slots[i].as_mut() {
                                    // live point estimate: no calibrated
                                    // spread, so σ = 0 (quantiles = mean)
                                    s.predicted_remaining = Some(Prediction::new(
                                        preds[k] as f64,
                                        0.0,
                                        s.generated as u64,
                                    ));
                                    s.iters_since_predict = 0;
                                }
                            }
                        }
                    }
                    PredictorKind::Oracle | PredictorKind::Binned(_) => {
                        for &i in &predict_slots {
                            if let Some(s) = slots[i].as_mut() {
                                s.predicted_remaining = s.forced_remaining.map(|r| {
                                    Prediction::new(
                                        r.saturating_sub(s.generated) as f64,
                                        0.0,
                                        s.generated as u64,
                                    )
                                });
                                s.iters_since_predict = 0;
                            }
                        }
                    }
                    PredictorKind::None => {}
                }
            }

            // 5. completions
            for i in finished {
                let slot = slots[i]
                    .take()
                    .expect("finished indices point at occupied slots");
                kv_mgr.release(slot.id);
                let _ = events.send(DecodeEvent::Finished {
                    instance: self.id,
                    id: slot.id,
                    generated: slot.generated,
                    at: now,
                });
            }
            if !oom_victims.is_empty() {
                let _ = events.send(DecodeEvent::Oom {
                    instance: self.id,
                    victims: oom_victims,
                    at: now,
                });
            }

            // 6. state report
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            ewma_iter_ms = if any_steps { 0.9 * ewma_iter_ms + 0.1 * ms } else { ms };
            any_steps = true;
            let snapshot: Vec<SlotSnapshot> = slots
                .iter()
                .flatten()
                .map(|s| SlotSnapshot {
                    id: s.id,
                    tokens: kv_mgr.tokens_of(s.id).unwrap_or(0),
                    predicted_remaining: s.predicted_remaining,
                })
                .collect();
            let _ = events.send(DecodeEvent::Report {
                instance: self.id,
                slots: snapshot,
                ewma_iter_ms,
                kv_used: kv_mgr.used_tokens(),
                kv_capacity: kv_mgr.capacity_tokens(),
                at: Instant::now(),
            });
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn admit(
        &self,
        p: AdmitPayload,
        slots: &mut [Option<Slot>],
        kv_buf: &mut HostTensor,
        kv_mgr: &mut KvCacheManager,
        bucket: usize,
        max_batch: usize,
        events: &Sender<DecodeEvent>,
    ) {
        let active = slots.iter().flatten().count();
        let free_slot = (0..bucket).find(|&i| slots[i].is_none());
        let tokens_now = p.pos as u64 + p.replay.len() as u64;
        // admission watermark (vLLM-style): keep growth headroom so the
        // running batch does not immediately OOM-thrash — the SAME
        // definition the reschedulers' destination-feasibility guard uses
        let watermark_ok = kv_mgr.used_tokens() + tokens_now.max(1)
            <= admission_watermark(kv_mgr.capacity_tokens());
        let admissible = active < max_batch
            && free_slot.is_some()
            && watermark_ok
            && kv_mgr.would_fit(tokens_now.max(1));
        let Some(slot_idx) = free_slot.filter(|_| admissible) else {
            let _ = events.send(DecodeEvent::AdmitRejected {
                instance: self.id,
                payload: Box::new(p),
            });
            return;
        };
        kv_mgr
            .admit(p.id, tokens_now.max(1), self.id)
            .expect("would_fit checked");
        self.runtime
            .copy_kv_slot(&p.kv, 1, 0, kv_buf, bucket, slot_idx)
            .expect("kv slot copy");
        let (pos, next_token, replay) = if p.replay.is_empty() {
            (p.pos, p.next_token, VecDeque::new())
        } else {
            // recompute: start from scratch, feeding history
            let mut replay = p.replay;
            let first = replay.pop_front().unwrap_or(1);
            (0, first as i32, replay)
        };
        slots[slot_idx] = Some(Slot {
            id: p.id,
            pos,
            next_token,
            generated: p.generated,
            forced_remaining: p.forced_remaining,
            replay,
            token_history: Vec::new(),
            predicted_remaining: p.predicted_remaining,
            iters_since_predict: 0,
        });
    }

    fn migrate_out(
        &self,
        id: RequestId,
        slots: &mut [Option<Slot>],
        kv_buf: &mut HostTensor,
        kv_mgr: &mut KvCacheManager,
        bucket: usize,
        events: &Sender<DecodeEvent>,
    ) {
        let Some(idx) = (0..bucket).find(|&i| slots[i].as_ref().map(|s| s.id) == Some(id)) else {
            return; // finished in the meantime: stale decision, ignore
        };
        let slot = slots[idx]
            .take()
            .expect("migrate-out slot located by id scan above");
        kv_mgr.release(id);
        let kv = self
            .runtime
            .extract_kv_slot(kv_buf, bucket, idx)
            .expect("kv extract");
        let _ = events.send(DecodeEvent::MigratedOut {
            instance: self.id,
            payload: Box::new(AdmitPayload {
                id,
                kv,
                pos: slot.pos,
                next_token: slot.next_token,
                generated: slot.generated,
                forced_remaining: slot.forced_remaining,
                replay: VecDeque::new(),
                predicted_remaining: slot.predicted_remaining,
            }),
        });
    }
}
