//! The one shared quantile implementation.
//!
//! Three copies used to exist — `Percentiles::quantile` (linear
//! interpolation), the per-class percentiles reached through
//! `RunMetrics`, and `ReliabilityReport::quantile_requeue_s`
//! (nearest-rank, `.round()`) — with subtly different interpolation.
//! Every quantile in the crate now goes through [`quantile_sorted`]:
//! linear interpolation between the two straddling order statistics
//! (type-7 / numpy default), exact at q = 0 and q = 1.

/// Quantile of an ascending-sorted slice; `NaN` on empty input.
/// `q` is clamped to `[0, 1]`.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Quantile of an unsorted slice (sorts a copy); `NaN` on empty input.
pub fn quantile_unsorted(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
    quantile_sorted(&v, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_values_on_known_data() {
        let data: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((quantile_sorted(&data, 0.50) - 50.5).abs() < 1e-9);
        assert!((quantile_sorted(&data, 0.0) - 1.0).abs() < 1e-9);
        assert!((quantile_sorted(&data, 1.0) - 100.0).abs() < 1e-9);
        assert!((quantile_sorted(&data, 0.99) - 99.01).abs() < 0.02);
    }

    #[test]
    fn empty_is_nan_and_singleton_is_constant() {
        assert!(quantile_sorted(&[], 0.5).is_nan());
        assert!(quantile_unsorted(&[], 0.5).is_nan());
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert!((quantile_sorted(&[7.0], q) - 7.0).abs() < 1e-12);
        }
    }

    #[test]
    fn unsorted_matches_sorted_and_is_monotone_in_q() {
        let unsorted = [5.0, 1.0, 9.0, 3.0, 7.0];
        let mut sorted = unsorted;
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
        let mut prev = f64::NEG_INFINITY;
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let v = quantile_unsorted(&unsorted, q);
            assert!((v - quantile_sorted(&sorted, q)).abs() < 1e-12);
            assert!(v >= prev, "quantile must be monotone in q");
            assert!((1.0..=9.0).contains(&v), "within [min, max]");
            prev = v;
        }
    }

    #[test]
    fn out_of_range_q_clamps() {
        let data = [1.0, 2.0, 3.0];
        assert!((quantile_sorted(&data, -0.5) - 1.0).abs() < 1e-12);
        assert!((quantile_sorted(&data, 1.5) - 3.0).abs() < 1e-12);
    }
}
