//! Serving metrics: latency percentiles, goodput, load-variance tracking,
//! and the runtime trace recorder behind the paper's Figs. 3/11/12/13.

pub mod percentiles;
mod recorder;
mod variance;

pub use recorder::{TraceEvent, TraceRecorder, TraceRow};
pub use variance::{snapshot_variance, RunningVariance, VarianceOverTime};

use crate::workload::{RequestClass, SloByClass};
use crate::{RequestId, Time};

/// Exact percentile store. At our experiment sizes (<= a few million
/// samples) keeping raw samples is cheaper than a sketch and exact.
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
    }

    /// Exact quantile (linear interpolation, the crate-wide shared
    /// definition in [`percentiles::quantile_sorted`]).
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        percentiles::quantile_sorted(&self.samples, q)
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn max(&mut self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        *self.samples.last().unwrap()
    }
}

/// Per-request latency record, filled in as the request flows through the
/// system; consumed by [`RunMetrics`].
#[derive(Clone, Debug, Default)]
pub struct RequestLatency {
    /// Request id (joins per-class / per-session analyses to the trace).
    pub id: RequestId,
    /// Workload class the request belongs to.
    pub class: RequestClass,
    pub arrival: Time,
    pub prefill_done: Option<Time>,
    pub first_token: Option<Time>,
    pub finished: Option<Time>,
    pub output_tokens: u32,
    /// Full prompt length in tokens (for follow-up turns: prior context +
    /// the new user message).
    pub prompt_tokens: u32,
    /// Prompt tokens actually prefilled: equal to `prompt_tokens` on a
    /// prefix-cache miss (or with the cache off), only the new suffix on
    /// a hit — the per-turn evidence that cached turns skipped prefill
    /// work (`prompt_tokens - suffix_tokens` = reused prefix).
    pub suffix_tokens: u32,
    /// Mean time-per-output-token over the whole request (seconds).
    pub mean_tpot: Option<f64>,
    /// Max single-gap TPOT (captures migration stalls / overload spikes).
    pub max_tpot: Option<f64>,
    /// Number of times this request was migrated between decode instances.
    pub migrations: u32,
    /// Whether the request experienced an OOM-triggered recompute.
    pub hit_oom: bool,
}

impl RequestLatency {
    pub fn ttft(&self) -> Option<f64> {
        self.first_token.map(|t| t - self.arrival)
    }

    /// Finalize the TPOT fields at completion time from the driver's
    /// accumulated inter-token gaps. A single token has no gap, so its
    /// TPOT stays `None` — [`Self::meets_slo`] then judges it on TTFT
    /// alone (a `Some(0.0)` placeholder would inflate goodput). The one
    /// definition both drivers (sim + serve) share.
    pub fn finalize_tpot(&mut self, generated: u32, tpot_sum: f64, tpot_max: f64) {
        if generated > 1 {
            self.mean_tpot = Some(tpot_sum / (generated - 1) as f64);
            self.max_tpot = Some(tpot_max);
        } else {
            self.mean_tpot = None;
            self.max_tpot = None;
        }
    }

    /// Does this request meet `slo`? A single-token request has no
    /// inter-token gap, so its TPOT is `None` and the check is TTFT-only;
    /// a multi-token request with no recorded TPOT never qualifies.
    pub fn meets_slo(&self, slo: Slo) -> bool {
        let ttft_ok = self.ttft().map(|t| t <= slo.ttft_s).unwrap_or(false);
        let tpot_ok = match self.mean_tpot {
            Some(t) => t <= slo.tpot_s,
            None => self.output_tokens <= 1,
        };
        ttft_ok && tpot_ok
    }
}

/// One sample of the elastic pool's composition, taken once per scale
/// interval by both drivers — the instance-count timeline the elastic
/// bench plots and the determinism tests compare.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PoolSample {
    pub t: Time,
    pub prefill_active: usize,
    pub decode_active: usize,
    /// Instances draining out of either pool.
    pub draining: usize,
    /// Instances warming up toward either pool (provision or flip).
    pub provisioning: usize,
}

/// SLO definition (paper §6.2: 1 s TTFT; TPOT 25 ms for the 7B model).
#[derive(Clone, Copy, Debug)]
pub struct Slo {
    pub ttft_s: f64,
    pub tpot_s: f64,
}

impl Default for Slo {
    fn default() -> Self {
        // Paper large-cluster setting: TTFT 1 s, TPOT 25 ms.
        Slo {
            ttft_s: 1.0,
            tpot_s: 0.025,
        }
    }
}

/// Aggregated end-to-end run metrics (one Fig. 10 data point).
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub completed: Vec<RequestLatency>,
    pub duration: Time,
    pub oom_events: u64,
    pub migrations: u64,
}

impl RunMetrics {
    /// Requests finished per second.
    pub fn throughput(&self) -> f64 {
        if self.duration <= 0.0 {
            return 0.0;
        }
        self.completed.len() as f64 / self.duration
    }

    /// Rate of requests meeting the SLO (paper's goodput). Single-token
    /// requests carry no TPOT sample and are judged on TTFT alone — they
    /// must not unconditionally count as TPOT-compliant (a `Some(0.0)`
    /// placeholder used to inflate goodput).
    pub fn goodput(&self, slo: Slo) -> f64 {
        if self.duration <= 0.0 {
            return 0.0;
        }
        let good = self.completed.iter().filter(|r| r.meets_slo(slo)).count();
        good as f64 / self.duration
    }

    /// Rate of requests meeting the SLO of their OWN class — the per-class
    /// goodput definition scenario runs report (aggregate [`Self::goodput`]
    /// judges every class against one target and hides class violations).
    pub fn goodput_by_class(&self, slos: &SloByClass) -> f64 {
        if self.duration <= 0.0 {
            return 0.0;
        }
        let good = self
            .completed
            .iter()
            .filter(|r| r.meets_slo(slos.get(r.class)))
            .count();
        good as f64 / self.duration
    }

    /// Subset of this run belonging to one request class. Duration is the
    /// full run's (rates stay comparable); run-wide counters (OOMs,
    /// migrations) are not attributable per class and are zeroed.
    pub fn filter_class(&self, class: RequestClass) -> RunMetrics {
        RunMetrics {
            completed: self
                .completed
                .iter()
                .filter(|r| r.class == class)
                .cloned()
                .collect(),
            duration: self.duration,
            oom_events: 0,
            migrations: 0,
        }
    }

    /// Classes with at least one completed request, in canonical order.
    pub fn classes_present(&self) -> Vec<RequestClass> {
        RequestClass::ALL
            .into_iter()
            .filter(|c| self.completed.iter().any(|r| r.class == *c))
            .collect()
    }

    /// Quantile of per-request mean TPOT, in milliseconds.
    pub fn quantile_tpot_ms(&self, q: f64) -> f64 {
        let mut p = Percentiles::new();
        for r in &self.completed {
            if let Some(t) = r.mean_tpot {
                p.record(t * 1e3);
            }
        }
        p.quantile(q)
    }

    /// Quantile of TTFT, in milliseconds.
    pub fn quantile_ttft_ms(&self, q: f64) -> f64 {
        let mut p = Percentiles::new();
        for r in &self.completed {
            if let Some(t) = r.ttft() {
                p.record(t * 1e3);
            }
        }
        p.quantile(q)
    }

    /// P99 of per-request mean TPOT, in milliseconds (Fig. 10 bottom row).
    pub fn p99_tpot_ms(&self) -> f64 {
        self.quantile_tpot_ms(0.99)
    }

    pub fn p99_ttft_ms(&self) -> f64 {
        self.quantile_ttft_ms(0.99)
    }

    pub fn mean_tpot_ms(&self) -> f64 {
        let vals: Vec<f64> = self
            .completed
            .iter()
            .filter_map(|r| r.mean_tpot)
            .collect();
        if vals.is_empty() {
            return f64::NAN;
        }
        vals.iter().sum::<f64>() / vals.len() as f64 * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_exact_on_known_data() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.record(i as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert!((p.quantile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.quantile(1.0) - 100.0).abs() < 1e-9);
        assert!((p.p99() - 99.01).abs() < 0.02);
    }

    #[test]
    fn empty_percentiles_nan() {
        let mut p = Percentiles::new();
        assert!(p.p50().is_nan());
        assert!(p.mean().is_nan());
    }

    #[test]
    fn goodput_counts_only_slo_compliant() {
        let mk = |ttft: f64, tpot: f64| RequestLatency {
            arrival: 0.0,
            first_token: Some(ttft),
            mean_tpot: Some(tpot),
            finished: Some(10.0),
            output_tokens: 10,
            ..Default::default()
        };
        let m = RunMetrics {
            completed: vec![mk(0.5, 0.010), mk(2.0, 0.010), mk(0.5, 0.100)],
            duration: 10.0,
            ..Default::default()
        };
        let slo = Slo::default();
        assert!((m.throughput() - 0.3).abs() < 1e-12);
        assert!((m.goodput(slo) - 0.1).abs() < 1e-12); // only the first
    }

    #[test]
    fn single_token_requests_are_judged_on_ttft_only() {
        let slo = Slo::default();
        // 1-token request, good TTFT, no TPOT sample: counts
        let one_good = RequestLatency {
            arrival: 0.0,
            first_token: Some(0.5),
            finished: Some(0.5),
            output_tokens: 1,
            mean_tpot: None,
            ..Default::default()
        };
        assert!(one_good.meets_slo(slo));
        // 1-token request with a blown TTFT must NOT count (the old
        // Some(0.0) placeholder made every such request TPOT-compliant)
        let one_late = RequestLatency {
            first_token: Some(5.0),
            ..one_good.clone()
        };
        assert!(!one_late.meets_slo(slo));
        // multi-token request that somehow lost its TPOT sample: never
        // SLO-compliant (no evidence of decode pacing)
        let multi_missing = RequestLatency {
            output_tokens: 20,
            ..one_good.clone()
        };
        assert!(!multi_missing.meets_slo(slo));
        let m = RunMetrics {
            completed: vec![one_good, one_late, multi_missing],
            duration: 10.0,
            ..Default::default()
        };
        assert!((m.goodput(slo) - 0.1).abs() < 1e-12, "only the first counts");
    }

    #[test]
    fn per_class_goodput_judges_each_class_against_its_own_slo() {
        let mk = |class: RequestClass, ttft: f64, tpot: f64| RequestLatency {
            class,
            arrival: 0.0,
            first_token: Some(ttft),
            mean_tpot: Some(tpot),
            finished: Some(10.0),
            output_tokens: 10,
            ..Default::default()
        };
        // a 40 ms-TPOT reasoning request: violates the default 25 ms SLO
        // but meets reasoning's relaxed 50 ms target
        let m = RunMetrics {
            completed: vec![
                mk(RequestClass::Chat, 0.5, 0.010),
                mk(RequestClass::Reasoning, 1.5, 0.040),
            ],
            duration: 10.0,
            ..Default::default()
        };
        let uniform = SloByClass::uniform(Slo::default());
        assert!((m.goodput_by_class(&uniform) - 0.1).abs() < 1e-12);
        let relaxed = uniform.with(
            RequestClass::Reasoning,
            Slo {
                ttft_s: 2.0,
                tpot_s: 0.050,
            },
        );
        assert!((m.goodput_by_class(&relaxed) - 0.2).abs() < 1e-12);
        // class filters partition the completed set
        assert_eq!(m.filter_class(RequestClass::Chat).completed.len(), 1);
        assert_eq!(m.filter_class(RequestClass::Summarization).completed.len(), 0);
        assert_eq!(
            m.classes_present(),
            vec![RequestClass::Chat, RequestClass::Reasoning]
        );
    }

    #[test]
    fn ttft_from_first_token() {
        let r = RequestLatency {
            arrival: 5.0,
            first_token: Some(5.8),
            ..Default::default()
        };
        assert!((r.ttft().unwrap() - 0.8).abs() < 1e-12);
    }
}
