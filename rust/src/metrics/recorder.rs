//! Runtime trace recorder — produces the Fig. 12-style traces: per-instance
//! KV-cache usage over time, OOM windows, and rescheduling-event ticks.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

use crate::{InstanceId, RequestId, Time};

/// Discrete events worth marking on a trace.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// Periodic sample of an instance's KV usage (fraction of capacity)
    /// and current batched-token load.
    KvSample {
        instance: InstanceId,
        kv_frac: f64,
        tokens: u64,
        batch: usize,
    },
    /// A migration decided by the rescheduler.
    Migration {
        request: RequestId,
        src: InstanceId,
        dst: InstanceId,
        kv_tokens: u64,
    },
    /// An OOM on an instance: victims forced to recompute.
    Oom {
        instance: InstanceId,
        victims: usize,
    },
    /// An OOM victim re-entering the prefill queue for KV recompute.
    /// Distinct from [`TraceEvent::Arrived`] so trace consumers counting
    /// arrivals see each request exactly once.
    RecomputeQueued { request: RequestId },
    /// Request lifecycle markers.
    Arrived { request: RequestId },
    PrefillDone { request: RequestId, instance: InstanceId },
    Finished { request: RequestId, instance: InstanceId },
}

/// One timestamped row.
#[derive(Clone, Debug)]
pub struct TraceRow {
    pub t: Time,
    pub event: TraceEvent,
}

/// In-memory event log with TSV export; cheap enough to keep always-on at
/// our scales (the live runtime samples KV usage at the scheduler interval).
#[derive(Clone, Debug, Default)]
pub struct TraceRecorder {
    rows: Vec<TraceRow>,
    enabled: bool,
}

impl TraceRecorder {
    pub fn new(enabled: bool) -> Self {
        TraceRecorder {
            rows: Vec::new(),
            enabled,
        }
    }

    #[inline]
    pub fn record(&mut self, t: Time, event: TraceEvent) {
        if self.enabled {
            self.rows.push(TraceRow { t, event });
        }
    }

    pub fn rows(&self) -> &[TraceRow] {
        &self.rows
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Max KV usage fraction across instances over time (Fig. 12's curve).
    /// Returns (time, max_kv_frac) downsampled per instance-sweep.
    pub fn max_kv_series(&self, n_instances: usize) -> Vec<(Time, f64)> {
        let mut cur = vec![0.0f64; n_instances];
        let mut out = Vec::new();
        for row in &self.rows {
            if let TraceEvent::KvSample { instance, kv_frac, .. } = row.event {
                if instance < n_instances {
                    cur[instance] = kv_frac;
                    let mx = cur.iter().cloned().fold(0.0, f64::max);
                    out.push((row.t, mx));
                }
            }
        }
        out
    }

    /// Times of rescheduling (migration) events — Fig. 12's vertical ticks.
    pub fn migration_times(&self) -> Vec<Time> {
        self.rows
            .iter()
            .filter(|r| matches!(r.event, TraceEvent::Migration { .. }))
            .map(|r| r.t)
            .collect()
    }

    /// (start,instance) of each OOM event — Fig. 12's shaded regions.
    pub fn oom_times(&self) -> Vec<(Time, InstanceId)> {
        self.rows
            .iter()
            .filter_map(|r| match r.event {
                TraceEvent::Oom { instance, .. } => Some((r.t, instance)),
                _ => None,
            })
            .collect()
    }

    /// TSV export for offline plotting.
    pub fn write_tsv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "time\tevent\tinstance\trequest\tkv_frac\ttokens\textra")?;
        for row in &self.rows {
            let mut line = String::new();
            write!(line, "{:.6}\t", row.t).unwrap();
            match &row.event {
                TraceEvent::KvSample { instance, kv_frac, tokens, batch } => {
                    write!(line, "kv\t{instance}\t\t{kv_frac:.4}\t{tokens}\t{batch}").unwrap()
                }
                TraceEvent::Migration { request, src, dst, kv_tokens } => {
                    write!(line, "migration\t{src}\t{request}\t\t{kv_tokens}\tdst={dst}").unwrap()
                }
                TraceEvent::Oom { instance, victims } => {
                    write!(line, "oom\t{instance}\t\t\t\tvictims={victims}").unwrap()
                }
                TraceEvent::RecomputeQueued { request } => {
                    write!(line, "recompute_queued\t\t{request}\t\t\t").unwrap()
                }
                TraceEvent::Arrived { request } => {
                    write!(line, "arrived\t\t{request}\t\t\t").unwrap()
                }
                TraceEvent::PrefillDone { request, instance } => {
                    write!(line, "prefill_done\t{instance}\t{request}\t\t\t").unwrap()
                }
                TraceEvent::Finished { request, instance } => {
                    write!(line, "finished\t{instance}\t{request}\t\t\t").unwrap()
                }
            }
            writeln!(f, "{line}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let mut r = TraceRecorder::new(false);
        r.record(1.0, TraceEvent::Arrived { request: 1 });
        assert!(r.is_empty());
    }

    #[test]
    fn max_kv_series_tracks_max_across_instances() {
        let mut r = TraceRecorder::new(true);
        r.record(0.0, TraceEvent::KvSample { instance: 0, kv_frac: 0.2, tokens: 10, batch: 1 });
        r.record(1.0, TraceEvent::KvSample { instance: 1, kv_frac: 0.9, tokens: 90, batch: 2 });
        r.record(2.0, TraceEvent::KvSample { instance: 0, kv_frac: 0.5, tokens: 50, batch: 1 });
        let s = r.max_kv_series(2);
        assert_eq!(s.len(), 3);
        assert!((s[1].1 - 0.9).abs() < 1e-12);
        assert!((s[2].1 - 0.9).abs() < 1e-12); // instance 1 still at 0.9
    }

    #[test]
    fn recompute_queue_events_do_not_count_as_arrivals() {
        let mut r = TraceRecorder::new(true);
        r.record(0.0, TraceEvent::Arrived { request: 3 });
        r.record(4.0, TraceEvent::Oom { instance: 0, victims: 1 });
        r.record(4.0, TraceEvent::RecomputeQueued { request: 3 });
        let arrivals: Vec<_> = r
            .rows()
            .iter()
            .filter_map(|row| match row.event {
                TraceEvent::Arrived { request } => Some(request),
                _ => None,
            })
            .collect();
        assert_eq!(arrivals, vec![3], "recompute must not double-count arrival");
    }

    #[test]
    fn migration_and_oom_extraction() {
        let mut r = TraceRecorder::new(true);
        r.record(3.0, TraceEvent::Migration { request: 7, src: 0, dst: 1, kv_tokens: 100 });
        r.record(5.0, TraceEvent::Oom { instance: 2, victims: 4 });
        assert_eq!(r.migration_times(), vec![3.0]);
        assert_eq!(r.oom_times(), vec![(5.0, 2)]);
    }
}
