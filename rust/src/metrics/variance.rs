//! Load-variance tracking — the paper's core balance metric (Eq. 3) and
//! the execution-time-variance-over-time series of Figs. 11/13.

use crate::Time;

/// Welford online mean/variance over a stream of values.
#[derive(Clone, Debug, Default)]
pub struct RunningVariance {
    n: u64,
    mean: f64,
    m2: f64,
}

impl RunningVariance {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }
}

/// Population variance of a snapshot (paper Eq. 3 over instance loads).
pub fn snapshot_variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Time series of cross-instance variance samples: push a per-instance
/// snapshot at each scheduling interval, read back the series (Fig. 11)
/// and its time-average (Fig. 13's y-axis).
#[derive(Clone, Debug, Default)]
pub struct VarianceOverTime {
    samples: Vec<(Time, f64)>,
}

impl VarianceOverTime {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the variance of instance metric `xs` (e.g. per-step decode
    /// latency in ms, or token load) at time `t`.
    pub fn snapshot(&mut self, t: Time, xs: &[f64]) {
        self.samples.push((t, snapshot_variance(xs)));
    }

    pub fn push_value(&mut self, t: Time, var: f64) {
        self.samples.push((t, var));
    }

    pub fn series(&self) -> &[(Time, f64)] {
        &self.samples
    }

    /// Time-averaged variance (rectangle rule over sample spacing).
    pub fn time_average(&self) -> f64 {
        if self.samples.len() < 2 {
            return self.samples.first().map(|s| s.1).unwrap_or(0.0);
        }
        let mut area = 0.0;
        let mut span = 0.0;
        for w in self.samples.windows(2) {
            let dt = w[1].0 - w[0].0;
            area += w[0].1 * dt;
            span += dt;
        }
        if span > 0.0 {
            area / span
        } else {
            0.0
        }
    }

    /// Mean of the raw samples (used when sampling is uniform).
    pub fn sample_mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|s| s.1).sum::<f64>() / self.samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut rv = RunningVariance::new();
        for &x in &xs {
            rv.push(x);
        }
        assert!((rv.variance() - snapshot_variance(&xs)).abs() < 1e-12);
        assert!((rv.mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn snapshot_variance_balanced_is_zero() {
        assert_eq!(snapshot_variance(&[5.0, 5.0, 5.0]), 0.0);
        assert_eq!(snapshot_variance(&[]), 0.0);
    }

    #[test]
    fn time_average_weights_by_dt() {
        let mut v = VarianceOverTime::new();
        v.push_value(0.0, 1.0); // holds for 1s
        v.push_value(1.0, 3.0); // holds for 3s
        v.push_value(4.0, 0.0);
        // (1*1 + 3*3) / 4 = 2.5
        assert!((v.time_average() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn imbalance_increases_variance() {
        let balanced = snapshot_variance(&[100.0, 100.0, 100.0]);
        let skewed = snapshot_variance(&[10.0, 100.0, 290.0]);
        assert!(skewed > balanced + 1000.0);
    }
}
