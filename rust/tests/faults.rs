//! Failure-injection and heterogeneous-fleet integration tests: the
//! crash path must re-queue displaced work without losing requests
//! (roomy capacity), the stochastic fault schedule must be a pure
//! function of the seed, and the fleet scenarios must be registered and
//! buildable.

use star::bench::scenarios::{small_cluster, ScenarioRegistry};
use star::sim::{SimParams, Simulator};
use star::workload::{Dataset, FaultConfig, FaultEvent, FleetSpec, TraceGen};

/// Drain-vs-crash differential: a scripted mid-run crash discards
/// in-flight decode KV (recomputed via the re-queue path) but never a
/// whole request — with capacity to spare, both the faultless baseline
/// and the crash run complete every request with exact token totals.
#[test]
fn scripted_crash_loses_tokens_never_requests() {
    let mut exp = small_cluster(Dataset::ShareGpt, 1.0, 42);
    exp.cluster.kv_capacity_tokens = 300_000; // roomy: watermark never terminal
    let trace = TraceGen::new(Dataset::ShareGpt, 1.0).generate(100, 42);
    let want: u64 = trace.iter().map(|r| r.output_len as u64).sum();

    let baseline = Simulator::new(
        SimParams {
            exp: exp.clone(),
            validate_state: true,
            ..Default::default()
        },
        &trace,
    )
    .run();
    assert_eq!(baseline.n_failed, 0);
    assert!(baseline.reliability.is_empty());
    let base_done: u64 = baseline
        .completed
        .iter()
        .map(|l| l.output_tokens as u64)
        .sum();
    assert_eq!(base_done, want);

    // same workload, but decode instance 0 crashes at t=60s (well into
    // steady state) and recovers 40s later
    exp.faults = Some(FaultConfig {
        mtbf_s: 0.0,
        mttr_s: 0.0,
        max_failures: 0,
        script: vec![FaultEvent {
            at: 60.0,
            instance: 0,
            down_s: 40.0,
        }],
    });
    let crashed = Simulator::new(
        SimParams {
            exp,
            validate_state: true,
            ..Default::default()
        },
        &trace,
    )
    .run();
    let rel = &crashed.reliability;
    assert_eq!(rel.failures, 1, "the scripted crash must execute");
    assert_eq!(rel.recoveries, 1, "the instance must come back after 40s");
    assert!(
        rel.requeued >= 1,
        "a crash 60s into a 1 rps run must displace in-flight work"
    );
    assert!(
        rel.kv_tokens_dropped > 0,
        "displaced residents must surrender their KV"
    );
    assert_eq!(rel.lost, 0, "roomy capacity: nothing may fail terminally");
    assert_eq!(crashed.n_failed, 0);
    assert_eq!(
        crashed.completed.len() + crashed.n_failed,
        crashed.n_requests,
        "accounting must close"
    );
    let done: u64 = crashed
        .completed
        .iter()
        .map(|l| l.output_tokens as u64)
        .sum();
    assert_eq!(
        done, want,
        "recomputed requests must regenerate their exact outputs"
    );
    assert_eq!(
        rel.requeue_delays.len() as u64,
        rel.requeued,
        "every re-queued request must re-admit (none stranded)"
    );
}

/// The stochastic failure schedule is drawn from a dedicated PRNG stream
/// off the run seed: same seed ⇒ identical failure times, identical
/// re-queue traces, identical reliability report.
#[test]
fn stochastic_faults_are_deterministic_per_seed() {
    let run = || {
        let mut exp = small_cluster(Dataset::ShareGpt, 0.5, 7);
        exp.cluster.kv_capacity_tokens = 300_000;
        exp.faults = Some(FaultConfig {
            mtbf_s: 60.0,
            mttr_s: 10.0,
            max_failures: 5,
            script: Vec::new(),
        });
        let trace = TraceGen::new(Dataset::ShareGpt, 0.5).generate(80, 7);
        Simulator::new(
            SimParams {
                exp,
                validate_state: true,
                ..Default::default()
            },
            &trace,
        )
        .run()
    };
    let a = run();
    let b = run();
    assert!(
        a.reliability.failures > 0,
        "mtbf 60s over this run must produce failures"
    );
    assert_eq!(
        a.reliability, b.reliability,
        "same seed must reproduce the failure schedule, re-queue trace, \
         and counters exactly"
    );
    assert_eq!(a.completed.len(), b.completed.len());
    assert_eq!(a.n_failed, b.n_failed);
}

/// Changing only the seed must change the stochastic failure schedule
/// (the stream is seeded off the run seed, not a constant).
#[test]
fn stochastic_fault_schedule_varies_with_seed() {
    let run = |seed: u64| {
        let mut exp = small_cluster(Dataset::ShareGpt, 0.5, seed);
        exp.cluster.kv_capacity_tokens = 300_000;
        exp.faults = Some(FaultConfig {
            mtbf_s: 60.0,
            mttr_s: 10.0,
            max_failures: 5,
            script: Vec::new(),
        });
        let trace = TraceGen::new(Dataset::ShareGpt, 0.5).generate(60, seed);
        Simulator::new(
            SimParams {
                exp,
                ..Default::default()
            },
            &trace,
        )
        .run()
    };
    let a = run(7);
    let b = run(8);
    assert_ne!(
        a.reliability.failure_log, b.reliability.failure_log,
        "different seeds must draw different failure times"
    );
}

/// A heterogeneous fleet with hardware-aware dispatch completes every
/// request with exact token totals — mem_mult scales real capacity and
/// speed_mult only bends modeled time, so conservation is untouched.
#[test]
fn heterogeneous_fleet_conserves_tokens() {
    let mut exp = small_cluster(Dataset::ShareGpt, 0.4, 13);
    exp.cluster.kv_capacity_tokens = 300_000;
    exp.fleet = Some(FleetSpec::from_mults(&[1.0, 0.5], &[1.0, 2.0]));
    exp.dispatch_policy = "hardware_aware".to_string();
    exp.predictor = "oracle".to_string();
    let trace = TraceGen::new(Dataset::ShareGpt, 0.4).generate(80, 13);
    let report = Simulator::new(
        SimParams {
            exp,
            validate_state: true,
            ..Default::default()
        },
        &trace,
    )
    .run();
    assert_eq!(report.n_failed, 0);
    let done: u64 = report.completed.iter().map(|l| l.output_tokens as u64).sum();
    let want: u64 = trace.iter().map(|r| r.output_len as u64).sum();
    assert_eq!(done, want);
}

/// The fleet scenarios ship in the registry and build valid specs with
/// faults/fleet attached where the scenario calls for them.
#[test]
fn fleet_scenarios_are_registered_and_build() {
    let reg = ScenarioRegistry::with_builtins();
    let names = reg.names();
    for required in ["degraded_fleet", "mixed_gen"] {
        assert!(
            names.iter().any(|n| n.as_str() == required),
            "scenario `{required}` must be registered (have: {names:?})"
        );
    }
    let exp = small_cluster(Dataset::ShareGpt, 0.3, 5);
    let degraded = reg.build("degraded_fleet", &exp).expect("degraded_fleet builds");
    assert!(degraded.faults.is_some(), "degraded_fleet injects faults");
    assert!(degraded.fleet.is_some(), "degraded_fleet mixes hardware");
    let mixed = reg.build("mixed_gen", &exp).expect("mixed_gen builds");
    assert!(mixed.faults.is_none(), "mixed_gen is fault-free");
    assert!(mixed.fleet.is_some(), "mixed_gen mixes hardware");
    // the specs generate usable traces
    let t = degraded.generate(20, 5);
    assert_eq!(t.requests.len(), 20);
}
