//! Integration tests: rust runtime × real AOT artifacts.
//!
//! These exercise the full L1/L2/L3 composition: Pallas kernels lowered
//! into HLO by jax, loaded and executed through PJRT from rust. They are
//! skipped (with a notice) if `make artifacts` has not run.

use star::runtime::{artifacts_dir, HostTensor, StarRuntime};

fn runtime() -> Option<StarRuntime> {
    let dir = match artifacts_dir(None) {
        Ok(d) => d,
        Err(_) => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return None;
        }
    };
    Some(StarRuntime::load(&dir).expect("artifacts load"))
}

#[test]
fn prefill_produces_finite_outputs() {
    let Some(rt) = runtime() else { return };
    let out = rt.prefill(b"\x01Qhello world?").unwrap();
    assert_eq!(out.logits.len(), rt.meta.vocab);
    assert_eq!(out.hidden.len(), rt.meta.d_model);
    assert!(out.logits.iter().all(|x| x.is_finite()));
    assert_eq!(out.kv.len(), rt.meta.kv_elems(1));
}

#[test]
fn prefill_rejects_bad_lengths() {
    let Some(rt) = runtime() else { return };
    assert!(rt.prefill(b"").is_err());
    let long = vec![b'a'; rt.meta.max_prompt + 1];
    assert!(rt.prefill(&long).is_err());
}

#[test]
fn decode_step_matches_across_buckets() {
    // The same request placed in bucket-1 and bucket-4 (slot 2) must
    // produce identical logits: batching must not change numerics.
    let Some(rt) = runtime() else { return };
    let pre = rt.prefill(b"\x01Qdeterminism?").unwrap();
    let plen = b"\x01Qdeterminism?".len();

    // bucket 1
    let mut kv1 = rt.new_kv_buffer(1);
    rt.copy_kv_slot(&pre.kv, 1, 0, &mut kv1, 1, 0).unwrap();
    let o1 = rt.decode_step(1, &[42], &[plen as i32], &kv1).unwrap();

    // bucket 4, slot 2 (other slots idle at pos 0)
    let mut kv4 = rt.new_kv_buffer(4);
    rt.copy_kv_slot(&pre.kv, 1, 0, &mut kv4, 4, 2).unwrap();
    let o4 = rt
        .decode_step(4, &[1, 1, 42, 1], &[0, 0, plen as i32, 0], &kv4)
        .unwrap();

    let v = rt.meta.vocab;
    for i in 0..v {
        let a = o1.logits[i];
        let b = o4.logits[2 * v + i];
        assert!(
            (a - b).abs() < 1e-4,
            "logit {i} differs across buckets: {a} vs {b}"
        );
    }
}

#[test]
fn greedy_continuation_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let prompt = b"\x01Qaaaa?";
    let mut seqs = Vec::new();
    for _ in 0..2 {
        let pre = rt.prefill(prompt).unwrap();
        let mut kv = rt.new_kv_buffer(1);
        rt.copy_kv_slot(&pre.kv, 1, 0, &mut kv, 1, 0).unwrap();
        let mut pos = prompt.len() as i32;
        let mut tok = argmax(&pre.logits) as i32;
        let mut seq = vec![tok];
        for _ in 0..16 {
            let out = rt.decode_step(1, &[tok], &[pos], &kv).unwrap();
            kv = out.kv;
            tok = argmax(&out.logits) as i32;
            pos += 1;
            seq.push(tok);
        }
        seqs.push(seq);
    }
    assert_eq!(seqs[0], seqs[1]);
}

#[test]
fn trained_model_generates_corpus_shaped_text() {
    // the pre-trained LM should emit the reasoning-trace alphabet
    // (step headers / filler / newline) rather than random bytes, and
    // should terminate with EOS on a short-tag prompt. Generation uses
    // temperature sampling (greedy never terminates on a language whose
    // length is stochastic — P(continue) > P(EOS) pointwise).
    let Some(rt) = runtime() else { return };
    let mut rng = star::prng::Pcg64::new(7, 1);
    let prompt = b"\x01Qaxyzw?"; // tag 'a' = shortest expected output
    let pre = rt.prefill(prompt).unwrap();
    let mut kv = rt.new_kv_buffer(1);
    rt.copy_kv_slot(&pre.kv, 1, 0, &mut kv, 1, 0).unwrap();
    let mut pos = prompt.len() as i32;
    let mut tok = sample(&pre.logits, 0.9, &mut rng) as i32;
    let mut bytes = Vec::new();
    for _ in 0..400 {
        if tok == rt.meta.eos as i32 {
            break;
        }
        bytes.push(tok as u8);
        let out = rt.decode_step(1, &[tok], &[pos], &kv).unwrap();
        kv = out.kv;
        tok = sample(&out.logits, 0.9, &mut rng) as i32;
        pos += 1;
    }
    assert!(
        bytes.len() < 400,
        "short-tag prompt should hit EOS well before 400 tokens; got {} bytes: {:?}",
        bytes.len(),
        String::from_utf8_lossy(&bytes)
    );
    let corpus_bytes = bytes
        .iter()
        .filter(|&&b| b"etaoinshrdlucmfwyp0123456789s:*\n".contains(&b))
        .count();
    assert!(
        corpus_bytes * 10 >= bytes.len() * 8,
        "generated text should be mostly corpus alphabet: {:?}",
        String::from_utf8_lossy(&bytes)
    );
}

#[test]
fn predictor_orders_early_vs_late_hidden_states() {
    // remaining-length prediction should be larger right after the prompt
    // than near the end of a long generation (on average).
    let Some(rt) = runtime() else { return };
    let prompt = b"\x01Qpzzzz?"; // tag 'p' = longest expected output
    let pre = rt.prefill(prompt).unwrap();
    let early = rt.predict_remaining(&pre.hidden).unwrap()[0];

    // run a long generation and take a late hidden state
    let mut kv = rt.new_kv_buffer(1);
    rt.copy_kv_slot(&pre.kv, 1, 0, &mut kv, 1, 0).unwrap();
    let mut pos = prompt.len() as i32;
    let mut tok = argmax(&pre.logits) as i32;
    let mut last_hidden = pre.hidden.clone();
    for _ in 0..300 {
        if tok == rt.meta.eos as i32 {
            break;
        }
        let out = rt.decode_step(1, &[tok], &[pos], &kv).unwrap();
        kv = out.kv;
        last_hidden = out.hidden.clone();
        tok = argmax(&out.logits) as i32;
        pos += 1;
    }
    let late = rt.predict_remaining(&last_hidden).unwrap()[0];
    assert!(
        early > late,
        "predictor should see more remaining early ({early}) than late ({late})"
    );
    assert!(early >= 0.0 && late >= 0.0);
}

#[test]
fn predictor_batches_match_single() {
    let Some(rt) = runtime() else { return };
    let pre = rt.prefill(b"\x01Qmmmmm?").unwrap();
    let single = rt.predict_remaining(&pre.hidden).unwrap()[0];
    let mut batch = Vec::new();
    for _ in 0..3 {
        batch.extend_from_slice(&pre.hidden);
    }
    let batched = rt.predict_remaining(&batch).unwrap();
    assert_eq!(batched.len(), 3);
    for b in batched {
        assert!((b - single).abs() < 1e-3, "{b} vs {single}");
    }
}

#[test]
fn kv_slot_copy_roundtrip() {
    let Some(rt) = runtime() else { return };
    let pre = rt.prefill(b"\x01Qroundtrip?").unwrap();
    let mut kv8 = rt.new_kv_buffer(8);
    rt.copy_kv_slot(&pre.kv, 1, 0, &mut kv8, 8, 5).unwrap();
    let back = rt.extract_kv_slot(&kv8, 8, 5).unwrap();
    assert_eq!(back.as_f32().unwrap(), pre.kv.as_f32().unwrap());
    // out-of-range slots rejected
    let mut kv2 = rt.new_kv_buffer(2);
    assert!(rt.copy_kv_slot(&pre.kv, 1, 0, &mut kv2, 2, 2).is_err());
}

fn sample(logits: &[f32], temp: f32, rng: &mut star::prng::Pcg64) -> usize {
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let ws: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - mx) / temp) as f64).exp())
        .collect();
    let total: f64 = ws.iter().sum();
    let mut u = rng.next_f64() * total;
    for (i, w) in ws.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    ws.len() - 1
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}
