//! Sharded simulation core differential tests (ISSUE 10): the event loop
//! partitioned into per-shard queues with a deterministic epoch merge
//! must be a pure refactor of the serial engine. `--shards 1` is the
//! serial engine, and any shard count must replay the *identical*
//! trajectory — trace rows, per-request completions, and the entire
//! `SimReport` — because the total order `(time, order-key, global seq)`
//! is independent of how instances are partitioned.
//!
//! Coverage: shards ∈ {2, 3, 4} vs shards = 1 across three seeds and
//! three scenarios (including `multi_round` session chains and
//! `degraded_fleet` fault injection, whose `InstanceFailure` /
//! `DecodeStep` events route to instance-home shards), with
//! `validate_state` cross-checking the shard rollup against the engine.

use star::bench::scenarios::ScenarioRegistry;
use star::config::ExperimentConfig;
use star::coordinator::PolicyRegistry;
use star::sim::{SimParams, SimReport, Simulator};

const SCENARIOS: &[&str] = &["bursty_mixed", "multi_round", "degraded_fleet"];
const SEEDS: &[u64] = &[11, 23, 47];

fn exp_for(scenario: &str, seed: u64, shards: usize) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    // five decode instances: every shard count in the sweep divides the
    // fleet *unevenly*, so slice/merge bugs can't hide behind symmetry
    exp.cluster.n_decode = 5;
    exp.cluster.n_prefill = 2;
    exp.cluster.rps = 0.6;
    exp.cluster.seed = seed;
    exp.cluster.kv_capacity_tokens = 200_000;
    exp.predictor = "oracle".to_string();
    exp.rescheduler.enabled = true;
    exp.record_traces = true;
    exp.scenario_name = Some(scenario.to_string());
    exp.shards = shards;
    exp
}

fn run(exp: ExperimentConfig, n: usize, validate: bool) -> SimReport {
    let spec = ScenarioRegistry::with_builtins()
        .build(exp.scenario_name.as_deref().unwrap(), &exp)
        .expect("builtin scenario");
    let trace = spec.generate(n, exp.cluster.seed);
    let params = SimParams {
        exp,
        validate_state: validate,
        ..Default::default()
    };
    Simulator::with_scenario(params, trace, &PolicyRegistry::with_builtins())
        .expect("builtin policies")
        .run()
}

/// Every recorded trace row, rendered exactly.
fn trace_rows(r: &SimReport) -> Vec<String> {
    r.recorder
        .rows()
        .iter()
        .map(|row| format!("{:.12}|{:?}", row.t, row.event))
        .collect()
}

/// Per-request completion fingerprint (sorted by id). `{:?}` on the f64
/// timestamps is exact, so equality here is bit-for-bit.
fn completion_rows(r: &SimReport) -> Vec<String> {
    let mut rows: Vec<String> = r
        .completed
        .iter()
        .map(|l| format!("{}|{:?}", l.id, l))
        .collect();
    rows.sort();
    rows
}

/// The whole report, rendered exactly — every field of [`SimReport`] is
/// a pure function of the event trajectory, so two runs that replay the
/// same trajectory must agree on all of it.
fn report_fingerprint(r: &SimReport) -> String {
    format!("{r:?}")
}

#[test]
fn shards_one_is_the_serial_engine_bit_for_bit() {
    // the serial-engine pin: the default config (shards = 1) and an
    // explicit --shards 1 run must be the same code path producing the
    // same bytes, replayable across repeated runs, and unperturbed by
    // the epoch-barrier cross-checks under validate_state
    for &scenario in SCENARIOS {
        let base = run(exp_for(scenario, 11, 1), 60, false);
        assert!(
            !base.completed.is_empty(),
            "{scenario}: fixture must complete requests"
        );
        assert!(
            !trace_rows(&base).is_empty(),
            "{scenario}: fixture must record trace rows"
        );
        let mut default_exp = exp_for(scenario, 11, 1);
        default_exp.shards = ExperimentConfig::default().shards;
        for (label, rerun) in [
            ("replay", run(exp_for(scenario, 11, 1), 60, false)),
            ("default-config", run(default_exp, 60, false)),
            ("validate_state", run(exp_for(scenario, 11, 1), 60, true)),
        ] {
            assert_eq!(
                trace_rows(&base),
                trace_rows(&rerun),
                "{scenario}/{label}: trace rows diverged from serial"
            );
            assert_eq!(completion_rows(&base), completion_rows(&rerun));
            assert_eq!(
                report_fingerprint(&base),
                report_fingerprint(&rerun),
                "{scenario}/{label}: report diverged from serial"
            );
        }
    }
}

#[test]
fn sharded_runs_replay_the_serial_trajectory() {
    // the tentpole contract: (seed, scenario) fixed, the trajectory is
    // invariant under shard count — trace rows, completions, and the
    // full report compare equal for shards ∈ {2, 4} vs the serial run
    for &scenario in SCENARIOS {
        for &seed in SEEDS {
            let base = run(exp_for(scenario, seed, 1), 60, false);
            assert!(
                !base.completed.is_empty(),
                "{scenario}/seed {seed}: fixture must complete requests"
            );
            for shards in [2usize, 4] {
                let sharded = run(exp_for(scenario, seed, shards), 60, false);
                assert_eq!(
                    trace_rows(&base),
                    trace_rows(&sharded),
                    "{scenario}/seed {seed}/shards {shards}: trace rows diverged"
                );
                assert_eq!(
                    completion_rows(&base),
                    completion_rows(&sharded),
                    "{scenario}/seed {seed}/shards {shards}: completions diverged"
                );
                assert_eq!(
                    report_fingerprint(&base),
                    report_fingerprint(&sharded),
                    "{scenario}/seed {seed}/shards {shards}: report diverged"
                );
            }
        }
    }
}

#[test]
fn validate_state_cross_checks_the_shard_rollup() {
    // validate_state asserts the merged shard aggregates against the
    // engine's own books at every epoch barrier; an uneven shard count
    // (5 instances over 3 shards) must pass and stay bit-for-bit
    let base = run(exp_for("degraded_fleet", 23, 1), 60, false);
    let checked = run(exp_for("degraded_fleet", 23, 3), 60, true);
    assert_eq!(trace_rows(&base), trace_rows(&checked));
    assert_eq!(report_fingerprint(&base), report_fingerprint(&checked));
    assert!(
        checked.reliability.failures > 0,
        "degraded_fleet must inject failures for this test to mean anything"
    );
}

#[test]
fn session_chains_survive_sharding() {
    // multi_round's follow-up turns are coordinator-routed events; the
    // realized chains must be identical lists of request ids per shard
    // count, and migrations (cross-shard hand-offs) must still happen
    let base = run(exp_for("multi_round", 47, 1), 80, false);
    assert!(
        !base.session_chains.is_empty(),
        "multi_round must realize session chains"
    );
    let sharded = run(exp_for("multi_round", 47, 4), 80, false);
    assert_eq!(base.session_chains, sharded.session_chains);
    assert_eq!(base.migrations, sharded.migrations);
    assert_eq!(base.reliability, sharded.reliability);
}

#[test]
fn obs_pipeline_is_invariant_under_shard_count() {
    // the observability subsystem samples gauges off cluster state at
    // simulated-time ticks; sharding must not move a single sample
    let mut on1 = exp_for("bursty_mixed", 11, 1);
    on1.obs.enabled = true;
    let mut on4 = exp_for("bursty_mixed", 11, 4);
    on4.obs.enabled = true;
    let a = run(on1, 60, false);
    let b = run(on4, 60, false);
    assert!(a.obs.enabled && a.obs.spans.seen > 0, "obs must be live");
    assert_eq!(format!("{:?}", a.obs), format!("{:?}", b.obs));
    assert_eq!(trace_rows(&a), trace_rows(&b));
}
