//! Third-party extensibility: register custom policies under new names and
//! run them through the simulator end-to-end, without touching coordinator
//! internals — the acceptance test for the pluggable-policy API.

use star::config::ExperimentConfig;
use star::coordinator::{
    ClusterView, DispatchPolicy, IncomingRequest, MigrationDecision, PolicyRegistry,
    ReschedulePolicy, ReschedulerStats,
};
use star::sim::{SimParams, Simulator};
use star::workload::{Dataset, TraceGen};
use star::InstanceId;

/// Dummy dispatch policy: pins every request to instance 0.
struct PinToZero;

impl DispatchPolicy for PinToZero {
    fn name(&self) -> &str {
        "pin_to_zero"
    }

    fn choose(&mut self, view: &ClusterView<'_>, _incoming: &IncomingRequest) -> InstanceId {
        view.instance(0).id()
    }
}

/// Dummy reschedule policy: observes every interval, never migrates.
#[derive(Default)]
struct CountOnly {
    stats: ReschedulerStats,
}

impl ReschedulePolicy for CountOnly {
    fn name(&self) -> &str {
        "count_only"
    }

    fn decide(&mut self, _view: &ClusterView<'_>) -> Vec<MigrationDecision> {
        self.stats.intervals += 1;
        Vec::new()
    }

    fn stats(&self) -> ReschedulerStats {
        self.stats.clone()
    }
}

fn experiment() -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_decode = 3;
    exp.cluster.n_requests = 30;
    exp.cluster.rps = 0.5;
    exp.cluster.kv_capacity_tokens = 400_000;
    exp.predictor = "oracle".to_string();
    exp
}

#[test]
fn custom_policies_run_through_the_simulator() {
    let mut registry = PolicyRegistry::with_builtins();
    registry.register_dispatch("pin_to_zero", |_| Ok(Box::new(PinToZero)));
    registry.register_reschedule("count_only", |_| Ok(Box::new(CountOnly::default())));

    let mut exp = experiment();
    exp.dispatch_policy = "pin_to_zero".to_string();
    exp.reschedule_policy = "count_only".to_string();
    let trace = TraceGen::new(Dataset::ShareGpt, exp.cluster.rps).generate(30, 42);
    let params = SimParams {
        exp,
        ..Default::default()
    };
    let report = Simulator::with_registry(params, &trace, &registry)
        .expect("custom policies resolve")
        .run();

    // the workload completes end-to-end under the custom policies
    assert_eq!(report.completed.len() + report.n_failed, 30);
    assert!(!report.completed.is_empty());
    // every decoded token landed on instance 0: the pin policy really ran
    assert!(report.per_instance_tokens[0] > 0);
    for (i, &t) in report.per_instance_tokens.iter().enumerate().skip(1) {
        assert_eq!(t, 0, "instance {i} decoded tokens despite pin_to_zero");
    }
    // the custom rescheduler was invoked every interval and never migrated
    assert!(report.scheduler_stats.intervals > 0);
    assert_eq!(report.migrations, 0);
}

#[test]
fn unknown_names_fail_construction_cleanly() {
    let registry = PolicyRegistry::with_builtins();
    let mut exp = experiment();
    exp.dispatch_policy = "pin_to_zero".to_string(); // not registered here
    let trace = TraceGen::new(Dataset::ShareGpt, 0.5).generate(5, 1);
    let err = Simulator::with_registry(
        SimParams {
            exp,
            ..Default::default()
        },
        &trace,
        &registry,
    )
    .err()
    .expect("unknown policy must not construct");
    assert!(err.to_string().contains("pin_to_zero"), "{err}");
}

#[test]
fn builtin_policy_matrix_runs_end_to_end() {
    // every (dispatch, reschedule) builtin pair drives the simulator to
    // completion — the registry is the only construction path
    let registry = PolicyRegistry::with_builtins();
    for dispatch in ["round_robin", "current_load", "predicted_load", "slo_aware"] {
        for reschedule in ["star", "memory_pressure", "none"] {
            let mut exp = experiment();
            exp.cluster.n_requests = 15;
            exp.dispatch_policy = dispatch.to_string();
            exp.reschedule_policy = reschedule.to_string();
            let trace = TraceGen::new(Dataset::ShareGpt, 0.5).generate(15, 7);
            let report = Simulator::with_registry(
                SimParams {
                    exp,
                    ..Default::default()
                },
                &trace,
                &registry,
            )
            .unwrap_or_else(|e| panic!("{dispatch}/{reschedule}: {e}"))
            .run();
            assert_eq!(
                report.completed.len() + report.n_failed,
                15,
                "{dispatch}/{reschedule} lost requests"
            );
        }
    }
}
