//! Prefix-cache subsystem integration tests (ISSUE 6): the `none` policy
//! must be bit-for-bit inert, cache-on runs must stay deterministic, the
//! cache-accounting invariant must hold under budget pressure (asserted
//! by `validate_state` after every event), and — gated on
//! `STAR_BENCH_SMOKE=1` — warm-cache session turns must beat `--cache
//! none` on later-turn TTFT.

use std::collections::HashSet;

use star::bench::scenarios::ScenarioRegistry;
use star::config::ExperimentConfig;
use star::coordinator::PolicyRegistry;
use star::prop::{prop_assert, property};
use star::sim::{SimParams, SimReport, Simulator};

fn session_exp(seed: u64) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_decode = 3;
    exp.cluster.n_prefill = 2;
    exp.cluster.rps = 0.5;
    exp.cluster.seed = seed;
    exp.cluster.kv_capacity_tokens = 400_000; // roomy: nothing fails
    exp.predictor = "oracle".to_string();
    exp.scenario_name = Some("multi_round".to_string());
    exp.record_traces = true;
    exp
}

fn run(exp: ExperimentConfig, n: usize, validate: bool) -> SimReport {
    let spec = ScenarioRegistry::with_builtins()
        .build(exp.scenario_name.as_deref().unwrap(), &exp)
        .expect("builtin scenario");
    let trace = spec.generate(n, exp.cluster.seed);
    let params = SimParams {
        exp,
        validate_state: validate,
        ..Default::default()
    };
    Simulator::with_scenario(params, trace, &PolicyRegistry::with_builtins())
        .expect("builtin policies")
        .run()
}

/// Every recorded trace row, rendered exactly — the bit-for-bit currency
/// of the differential tests.
fn trace_rows(r: &SimReport) -> Vec<String> {
    r.recorder
        .rows()
        .iter()
        .map(|row| format!("{:.12}|{:?}", row.t, row.event))
        .collect()
}

/// Per-request completion fingerprint (sorted by id).
fn completion_rows(r: &SimReport) -> Vec<String> {
    let mut rows: Vec<String> = r
        .completed
        .iter()
        .map(|l| {
            format!(
                "{}|{:?}|{:?}|{}|{}|{}",
                l.id, l.first_token, l.finished, l.output_tokens, l.prompt_tokens, l.suffix_tokens
            )
        })
        .collect();
    rows.sort();
    rows
}

#[test]
fn cache_none_is_bit_for_bit_inert() {
    // baseline: the defaults (cache off) — then `none` again with odd
    // budget/TTL knobs, and `none` under session_affinity dispatch (which
    // must degrade to current_load, the default, when no request ever
    // carries a preference). All three must produce identical traces.
    let base = run(session_exp(42), 60, false);
    assert!(!base.cache.enabled);
    assert_eq!(base.cache, Default::default());

    let mut odd_knobs = session_exp(42);
    odd_knobs.kvcache.policy = "none".to_string();
    odd_knobs.kvcache.budget_tokens = 12_345;
    odd_knobs.kvcache.ttl_s = 77.0;
    let b = run(odd_knobs, 60, false);

    let mut affinity = session_exp(42);
    affinity.dispatch_policy = "session_affinity".to_string();
    let c = run(affinity, 60, false);

    for (label, other) in [("odd none knobs", &b), ("session_affinity + none", &c)] {
        assert_eq!(
            trace_rows(&base),
            trace_rows(other),
            "{label}: traces must be bit-for-bit identical to the cache-off baseline"
        );
        assert_eq!(completion_rows(&base), completion_rows(other), "{label}");
        assert!((base.duration - other.duration).abs() < 1e-12, "{label}");
        assert_eq!(base.migrations, other.migrations, "{label}");
        assert_eq!(base.oom_events, other.oom_events, "{label}");
        assert!(!other.cache.enabled, "{label}");
    }
    // cache off: every turn prefills its full prompt
    for l in &base.completed {
        assert_eq!(l.suffix_tokens, l.prompt_tokens, "request {}", l.id);
    }
}

#[test]
fn cache_on_runs_are_same_seed_deterministic() {
    let mk = || {
        let mut exp = session_exp(7);
        exp.dispatch_policy = "session_affinity".to_string();
        exp.kvcache.policy = "lru".to_string();
        exp.kvcache.budget_tokens = 100_000;
        exp.kvcache.ttl_s = 300.0;
        run(exp, 60, true)
    };
    let a = mk();
    let b = mk();
    assert_eq!(trace_rows(&a), trace_rows(&b));
    assert_eq!(completion_rows(&a), completion_rows(&b));
    assert_eq!(a.cache, b.cache, "cache counters must be deterministic");
    assert!(a.cache.enabled);
    assert!(
        a.cache.hits + a.cache.misses > 0,
        "multi_round follow-ups must consult the cache: {:?}",
        a.cache
    );
}

#[test]
fn cache_accounting_invariant_holds_under_budget_pressure() {
    // validate_state reasserts after EVERY event that (a) the incremental
    // ClusterState mirror equals a from-scratch rebuild including cached
    // tokens, and (b) active KV + cached KV fits each instance — so this
    // property test's work is driving the cache through budget pressure,
    // TTL expiry, eviction, and tight-memory admission across seeds and
    // policies, then checking nothing leaked.
    property("cache accounting under pressure", 8, |g| {
        let seed = g.u64(0, 1 << 30);
        let mut exp = session_exp(seed);
        exp.cluster.kv_capacity_tokens = 40_000; // tight: real eviction
        exp.dispatch_policy = "session_affinity".to_string();
        exp.kvcache.policy = g.rng().choose(&["lru", "ttl", "predictive"]).to_string();
        let policy = exp.kvcache.policy.clone();
        exp.kvcache.budget_tokens = g.u64(2_000, 20_000); // tight budget
        exp.kvcache.ttl_s = g.f64(5.0, 120.0);
        exp.record_traces = false;
        let report = run(exp, 40, true);
        prop_assert(
            report.completed.len() + report.n_failed == report.n_requests,
            format!(
                "seed {seed} policy {policy}: leaked requests (completed {} + failed {} \
                 of {})",
                report.completed.len(),
                report.n_failed,
                report.n_requests
            ),
        )
    });
}

#[test]
fn kvcache_policy_strings_build_through_the_exp_path() {
    for policy in ["lru", "ttl", "predictive"] {
        let mut exp = session_exp(3);
        exp.dispatch_policy = "session_affinity".to_string();
        exp.kvcache.policy = policy.to_string();
        exp.kvcache.ttl_s = 200.0;
        exp.record_traces = false;
        exp.validate().expect("valid config");
        let report = run(exp, 30, false);
        assert!(report.cache.enabled, "{policy}");
        assert!(
            report.cache.insertions > 0,
            "{policy}: multi-round sessions must retain prefixes: {:?}",
            report.cache
        );
    }
}

/// Directional acceptance (STAR_BENCH_SMOKE=1 gate, like the bench smoke
/// suite): with session_affinity dispatch and a warm cache, later session
/// turns prefill only their suffix and their TTFT drops vs `--cache none`.
#[test]
fn warm_cache_cuts_later_turn_ttft_under_smoke_gate() {
    let gate = std::env::var("STAR_BENCH_SMOKE").unwrap_or_default();
    if gate.is_empty() || gate == "0" {
        eprintln!("skipped: set STAR_BENCH_SMOKE=1 to run the directional check");
        return;
    }
    let mk = |policy: &str| {
        let mut exp = session_exp(17);
        exp.dispatch_policy = "session_affinity".to_string();
        exp.kvcache.policy = policy.to_string();
        exp.kvcache.budget_tokens = 200_000;
        exp.kvcache.ttl_s = 600.0;
        exp.record_traces = false;
        run(exp, 120, false)
    };
    let cold = mk("none");
    let warm = mk("lru");
    assert!(warm.cache.hits > 0, "warm run must hit: {:?}", warm.cache);
    assert!(warm.cache.tokens_reused > 0, "{:?}", warm.cache);

    let later_ttft = |r: &SimReport| -> f64 {
        let later: HashSet<u64> = r
            .session_chains
            .iter()
            .flat_map(|c| c.iter().skip(1).copied())
            .collect();
        let samples: Vec<f64> = r
            .completed
            .iter()
            .filter(|l| later.contains(&l.id))
            .filter_map(|l| l.ttft())
            .collect();
        assert!(!samples.is_empty(), "no later-turn completions");
        samples.iter().sum::<f64>() / samples.len() as f64
    };
    let (c, w) = (later_ttft(&cold), later_ttft(&warm));
    assert!(
        w < c,
        "warm cache should cut later-turn TTFT: warm {w:.4}s vs cold {c:.4}s"
    );
    // and at least one warm turn demonstrably prefilled only a suffix
    assert!(
        warm.completed
            .iter()
            .any(|l| l.suffix_tokens < l.prompt_tokens),
        "no completed turn recorded a suffix-only prefill"
    );
}
