//! CLI error-surface tests: unknown `--dispatch` / `--reschedule` /
//! `--dataset` / `--scenario` values must fail loudly WITH the list of
//! valid names (they used to be silently ignored or reported without the
//! candidates), and the scenario path must run end-to-end.

use std::process::Command;

fn star() -> Command {
    Command::new(env!("CARGO_BIN_EXE_star"))
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = star().args(args).output().expect("spawn star binary");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn unknown_dispatch_lists_valid_names() {
    let (ok, _, err) = run(&["simulate", "--dispatch", "bogus", "--requests", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown dispatch policy `bogus`"), "{err}");
    assert!(err.contains("round_robin"), "must list candidates: {err}");
    assert!(err.contains("current_load"), "must list candidates: {err}");
}

#[test]
fn unknown_reschedule_lists_valid_names() {
    let (ok, _, err) = run(&["simulate", "--reschedule", "bogus", "--requests", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown reschedule policy `bogus`"), "{err}");
    assert!(err.contains("memory_pressure"), "must list candidates: {err}");
    assert!(err.contains("star"), "must list candidates: {err}");
}

#[test]
fn unknown_dataset_lists_valid_names() {
    for sub in ["simulate", "workload"] {
        let (ok, _, err) = run(&[sub, "--dataset", "bogus", "--requests", "1"]);
        assert!(!ok, "{sub} must fail on a bad dataset");
        assert!(err.contains("unknown dataset `bogus`"), "{sub}: {err}");
        assert!(err.contains("sharegpt|alpaca"), "{sub}: {err}");
    }
}

#[test]
fn unknown_scenario_lists_valid_names() {
    let (ok, _, err) = run(&["simulate", "--scenario", "bogus", "--requests", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown scenario `bogus`"), "{err}");
    assert!(err.contains("bursty_mixed"), "must list candidates: {err}");
    assert!(err.contains("multi_round"), "must list candidates: {err}");
}

#[test]
fn unknown_flag_still_reports_usage() {
    let (ok, _, err) = run(&["simulate", "--frobnicate"]);
    assert!(!ok);
    assert!(err.contains("unknown flag --frobnicate"), "{err}");
}

#[test]
fn bursty_scenario_simulation_runs_end_to_end_with_class_rows() {
    let (ok, out, err) = run(&[
        "simulate",
        "--scenario",
        "bursty_mixed",
        "--requests",
        "40",
        "--rps",
        "0.5",
        "--kv-capacity",
        "400000",
    ]);
    assert!(ok, "simulate --scenario bursty_mixed failed: {err}");
    assert!(out.contains("completed"), "missing summary line: {out}");
    // per-class rows (the violations the aggregate line hides)
    assert!(out.contains("class chat"), "missing chat row: {out}");
    assert!(out.contains("goodput"), "{out}");
}

#[test]
fn validate_bench_accepts_good_and_rejects_bad_json() {
    let dir = std::env::temp_dir().join("star_cli_validate_bench");
    std::fs::create_dir_all(&dir).unwrap();
    let good = dir.join("BENCH_good.json");
    let bad = dir.join("BENCH_bad.json");
    std::fs::write(&good, "{\"schema_version\": 1, \"bench\": \"good\"}\n").unwrap();
    std::fs::write(&bad, "{\"bench\": \"bad\"}\n").unwrap();
    let (ok, out, _) = run(&["validate-bench", good.to_str().unwrap()]);
    assert!(ok, "valid file must pass");
    assert!(out.contains("1 file(s) OK"), "{out}");
    let (ok, _, err) = run(&[
        "validate-bench",
        good.to_str().unwrap(),
        bad.to_str().unwrap(),
    ]);
    assert!(!ok, "missing schema_version must fail");
    assert!(err.contains("schema_version"), "{err}");
    let (ok, _, err) = run(&["validate-bench"]);
    assert!(!ok, "no files is an error");
    assert!(err.contains("at least one"), "{err}");
    std::fs::remove_file(&good).ok();
    std::fs::remove_file(&bad).ok();
}

#[test]
fn analyze_unknown_rule_lists_valid_ids() {
    let (ok, _, err) = run(&["analyze", "--rules", "R9"]);
    assert!(!ok);
    assert!(err.contains("unknown analyze rule `R9`"), "{err}");
    assert!(err.contains("R1|R2|R3|R4|R5"), "must list candidates: {err}");
}

#[test]
fn analyze_reports_fixture_findings_and_exits_nonzero() {
    let fixtures = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/analyze");
    let (ok, out, err) = run(&["analyze", fixtures]);
    assert!(!ok, "known-bad corpus must fail the pass");
    assert!(err.contains("violation"), "{err}");
    // machine-readable one-liners: path:line: Rn rule-name: message | snippet
    assert!(out.contains("sim/engine.rs:8: R1 no-hash-collections:"), "{out}");
    assert!(out.contains("| use std::collections::HashMap;"), "{out}");
    assert!(out.contains("coordinator/state.rs:7: R2 no-wall-clock:"), "{out}");
    assert!(out.contains("kvcache/unsafe_bad.rs:5: R3 unsafe-allowlist:"), "{out}");
    assert!(out.contains("sim/engine.rs:14: R4 no-bare-unwrap:"), "{out}");
    assert!(out.contains("R5 event-coverage:"), "{out}");
}

#[test]
fn analyze_rule_subset_and_clean_tree_exit_zero() {
    let src = concat!(env!("CARGO_MANIFEST_DIR"), "/src");
    let (ok, out, err) = run(&["analyze", src]);
    assert!(ok, "rust/src must be analyze-clean: {out}{err}");
    assert!(out.contains("0 finding(s)"), "{out}");
    // a subset selection runs only the named rules
    let fixtures = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/analyze");
    let (ok, out, _) = run(&["analyze", "--rules", "R4", fixtures]);
    assert!(!ok);
    assert!(out.contains("R4 no-bare-unwrap"), "{out}");
    assert!(!out.contains("R1 no-hash-collections"), "subset must skip R1: {out}");
}

#[test]
fn analyze_list_rules_prints_the_catalog() {
    let (ok, out, err) = run(&["analyze", "--list-rules"]);
    assert!(ok, "{err}");
    for needle in [
        "R1 no-hash-collections",
        "R2 no-wall-clock",
        "R3 unsafe-allowlist",
        "R4 no-bare-unwrap",
        "R5 event-coverage",
        "R6 trace-event-coverage",
        "R7 no-shared-mutable-static",
    ] {
        assert!(out.contains(needle), "missing `{needle}`: {out}");
    }
}

#[test]
fn trace_unknown_action_lists_valid_actions() {
    let (ok, _, err) = run(&["trace", "bogus", "--requests", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown trace action `bogus`"), "{err}");
    assert!(
        err.contains("summarize|slo-violations|export"),
        "must list candidates: {err}"
    );
}

#[test]
fn trace_unknown_export_format_lists_valid_formats() {
    // validated before the run: a typo must fail fast, not after a
    // full simulation
    let (ok, _, err) = run(&["trace", "export", "--format", "bogus", "--requests", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown trace export format `bogus`"), "{err}");
    assert!(err.contains("chrome|jsonl"), "must list candidates: {err}");
}

#[test]
fn unknown_predictor_lists_valid_names() {
    let (ok, _, err) = run(&["simulate", "--predictor", "bogus", "--requests", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown predictor `bogus`"), "{err}");
    for needle in ["none", "oracle", "binned2", "binned4", "binned6", "llm_native", "debiased"] {
        assert!(err.contains(needle), "must list candidate `{needle}`: {err}");
    }
}

#[test]
fn predictor_selects_any_registered_name_end_to_end() {
    // the acceptance claim: `star simulate --predictor <name>` selects any
    // registered predictor by string (alias spellings included), and the
    // display name that reaches the output is the registry key
    for name in ["debiased", "binned4", "4bin"] {
        let (ok, out, err) = run(&[
            "simulate",
            "--predictor",
            name,
            "--requests",
            "20",
            "--rps",
            "0.5",
            "--kv-capacity",
            "400000",
            "--verbose",
        ]);
        assert!(ok, "simulate --predictor {name} failed: {err}");
        assert!(out.contains("completed"), "{name}: missing summary: {out}");
    }
    // a predicting run reports its calibration scorecard
    let (ok, out, err) = run(&[
        "simulate",
        "--predictor",
        "llm_native",
        "--requests",
        "30",
        "--rps",
        "0.5",
        "--kv-capacity",
        "400000",
    ]);
    assert!(ok, "{err}");
    assert!(
        out.contains("predictor calibration"),
        "scorecard summary missing: {out}"
    );
    assert!(out.contains("MAE"), "{out}");
}

#[test]
fn unknown_scaling_lists_valid_names() {
    let (ok, _, err) = run(&["simulate", "--scaling", "bogus", "--requests", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown scaling policy `bogus`"), "{err}");
    assert!(err.contains("queue_pressure"), "must list candidates: {err}");
    assert!(err.contains("predictive"), "must list candidates: {err}");
}

#[test]
fn unknown_cache_policy_lists_valid_names() {
    let (ok, _, err) = run(&["simulate", "--cache", "bogus", "--requests", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown cache policy `bogus`"), "{err}");
    for needle in ["none", "lru", "ttl", "predictive"] {
        assert!(err.contains(needle), "must list candidate `{needle}`: {err}");
    }
}

#[test]
fn cache_enabled_simulation_runs_end_to_end_with_cache_summary() {
    let (ok, out, err) = run(&[
        "simulate",
        "--scenario",
        "multi_round",
        "--cache",
        "lru",
        "--dispatch",
        "session_affinity",
        "--requests",
        "40",
        "--rps",
        "0.5",
        "--kv-capacity",
        "400000",
    ]);
    assert!(ok, "simulate --cache lru failed: {err}");
    assert!(out.contains("completed"), "missing summary line: {out}");
    assert!(out.contains("prefix cache:"), "missing cache summary: {out}");
    assert!(out.contains("hit rate"), "{out}");
    // the cache summary only prints for cache-enabled runs
    let (ok, out, err) = run(&[
        "simulate",
        "--scenario",
        "multi_round",
        "--requests",
        "40",
        "--rps",
        "0.5",
        "--kv-capacity",
        "400000",
    ]);
    assert!(ok, "{err}");
    assert!(
        !out.contains("prefix cache:"),
        "cache-off run must not print a cache summary: {out}"
    );
}

#[test]
fn list_prints_registered_policies_and_scenarios() {
    let (ok, out, err) = run(&["list"]);
    assert!(ok, "star list failed: {err}");
    for needle in [
        "dispatch policies:",
        "reschedule policies:",
        "scaling policies:",
        "predictors:",
        "cache policies:",
        "scenarios:",
        "round_robin",
        "current_load",
        "slo_aware",
        "star",
        "memory_pressure",
        "static",
        "queue_pressure",
        "predictive",
        // the cache-policy registry (`--cache` candidates)
        "session_affinity",
        "lru",
        "ttl",
        // the predictor registry, so a new builtin cannot silently miss
        // registration (the registry unit test pins the exact list)
        "binned2",
        "binned4",
        "binned6",
        "llm_native",
        "debiased",
        "oracle",
        "bursty_mixed",
        "diurnal_chat",
        "multi_round",
        "stationary",
    ] {
        assert!(out.contains(needle), "star list missing `{needle}`: {out}");
    }
}

#[test]
fn elastic_simulation_runs_end_to_end() {
    let (ok, out, err) = run(&[
        "simulate",
        "--scenario",
        "diurnal_chat",
        "--scaling",
        "predictive",
        "--requests",
        "40",
        "--rps",
        "0.5",
        "--kv-capacity",
        "400000",
    ]);
    assert!(ok, "simulate --scaling predictive failed: {err}");
    assert!(out.contains("completed"), "missing summary line: {out}");
}
