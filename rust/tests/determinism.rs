//! Determinism regression tests for the PR-7 BTreeMap sweep (`star
//! analyze` R1): every structure keyed by `RequestId` in the scheduling
//! core now iterates in key order, so traces and decisions cannot depend
//! on hash-seed or insertion-order accidents.
//!
//! The instance pools themselves are `Vec`s (their construction order is
//! fixed by config), so the insertion-order freedom that R1 guards lives
//! in the request-keyed maps: these tests shuffle *request* admission
//! order where it feeds decisions (KV eviction victims, cluster-state
//! aggregates) and assert whole-run replay stability end to end.

use star::config::ExperimentConfig;
use star::coordinator::ClusterState;
use star::kvcache::KvCacheManager;
use star::sim::{SimParams, Simulator};
use star::workload::{Dataset, TraceGen};

/// Eviction-victim selection must depend only on the resident *set*,
/// never on the order requests were admitted. The sizes below include a
/// three-way tie (ids 2, 5, 9 at one block each) — exactly the case a
/// HashMap-backed allocator resolved by hash-iteration order.
#[test]
fn eviction_victims_independent_of_admission_order() {
    let admissions: Vec<(u64, u64)> = vec![
        (1, 500),
        (2, 10),
        (3, 300),
        (5, 12),
        (9, 8),
        (12, 120),
        (40, 64),
    ];
    let build = |order: &[usize]| {
        let mut m = KvCacheManager::new(16_000, 16);
        for &i in order {
            let (id, tokens) = admissions[i];
            m.admit(id, tokens, 0).expect("fixture fits");
        }
        m
    };
    let forward = build(&[0, 1, 2, 3, 4, 5, 6]);
    let shuffled = build(&[4, 6, 1, 0, 5, 3, 2]);
    for need in [1, 2, 5, 20, 60] {
        assert_eq!(
            forward.eviction_victims(need),
            shuffled.eviction_victims(need),
            "victim choice diverged at need={need}"
        );
    }
    // ties break by request id, smallest first (1-block residents 9, 2, 5)
    assert_eq!(forward.eviction_victims(3), vec![2, 5, 9]);
}

/// Cluster-state aggregates (the rescheduler's inputs) must be identical
/// for the same request *set* regardless of admission order.
#[test]
fn cluster_aggregates_independent_of_admission_order() {
    let admissions: Vec<(usize, u64, u64)> = vec![
        // (instance, request id, tokens)
        (0, 1, 400),
        (1, 2, 80),
        (0, 3, 80),
        (2, 4, 1200),
        (1, 5, 80),
        (2, 6, 30),
    ];
    let build = |order: &[usize]| {
        let mut cs = ClusterState::new(3, 4_000, 1.0, 0.05, 0.01);
        for &i in order {
            let (di, id, tokens) = admissions[i];
            cs.admit(di, id, tokens, None);
        }
        cs
    };
    let a = build(&[0, 1, 2, 3, 4, 5]);
    let b = build(&[5, 3, 1, 4, 2, 0]);
    for di in 0..3 {
        assert_eq!(a.stats(di).token_load(), b.stats(di).token_load());
        assert_eq!(a.stats(di).batch_size(), b.stats(di).batch_size());
        assert_eq!(a.stats(di).free_tokens(), b.stats(di).free_tokens());
        // membership is the same set (slot order legitimately differs)
        let mut ids_a: Vec<u64> = a.active(di).iter().map(|r| r.id).collect();
        let mut ids_b: Vec<u64> = b.active(di).iter().map(|r| r.id).collect();
        ids_a.sort_unstable();
        ids_b.sort_unstable();
        assert_eq!(ids_a, ids_b);
    }
}

/// End-to-end replay determinism with the full invariant checker on:
/// two runs from the same seed must produce bit-identical traces —
/// per-request arrival/first-token/finish times, migration counts, and
/// OOM flags. This is the property every benchmark delta rests on.
#[test]
fn sim_trace_identical_across_repeated_runs() {
    let run = || {
        let mut exp = ExperimentConfig::default();
        exp.cluster.n_requests = 160;
        exp.cluster.n_decode = 4;
        exp.cluster.n_prefill = 2;
        exp.cluster.rps = 4.0;
        exp.cluster.kv_capacity_tokens = 120_000; // tight: forces evictions
        exp.cluster.seed = 7;
        let trace = TraceGen::new(Dataset::ShareGpt, exp.cluster.rps)
            .generate(exp.cluster.n_requests, exp.cluster.seed);
        let params = SimParams {
            exp,
            validate_state: true,
            ..Default::default()
        };
        let report = Simulator::new(params, &trace).run();
        let mut lines: Vec<String> = report
            .completed
            .iter()
            .map(|l| {
                format!(
                    "{} {:.9} {:?} {:?} {:?} {} {} {}",
                    l.id,
                    l.arrival,
                    l.prefill_done,
                    l.first_token,
                    l.finished,
                    l.output_tokens,
                    l.migrations,
                    l.hit_oom
                )
            })
            .collect();
        lines.sort();
        (lines, report.completed.len())
    };
    let (a, n_a) = run();
    let (b, n_b) = run();
    assert!(n_a > 0, "fixture must complete requests");
    assert_eq!(n_a, n_b);
    assert_eq!(a, b, "same seed must replay to an identical trace");
}
