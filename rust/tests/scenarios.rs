//! Scenario-subsystem integration tests: determinism, distribution shape
//! of the new arrival processes, session-ordering invariants through the
//! simulator, and per-class SLO accounting (ISSUE 3 satellite coverage).

use star::bench::scenarios::{resolve_scenario, run_scenario_trace, ScenarioRegistry};
use star::config::ExperimentConfig;
use star::prng::Pcg64;
use star::sim::{SimParams, Simulator};
use star::workload::{ArrivalProcess, RequestClass};

fn base_exp(rps: f64, seed: u64) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_decode = 3;
    exp.cluster.rps = rps;
    exp.cluster.seed = seed;
    exp.cluster.kv_capacity_tokens = 400_000; // roomy: nothing fails
    exp.predictor = "oracle".to_string();
    exp
}

#[test]
fn every_builtin_scenario_generates_deterministically() {
    let reg = ScenarioRegistry::with_builtins();
    let exp = base_exp(0.5, 7);
    assert_eq!(
        reg.names(),
        vec!["bursty_mixed", "diurnal_chat", "multi_round", "stationary"]
    );
    for name in reg.names() {
        let spec = reg.build(&name, &exp).expect("builtin scenario builds");
        let a = spec.generate(300, 11);
        let b = spec.generate(300, 11);
        assert_eq!(a, b, "{name}: same seed must give an identical trace");
        let c = spec.generate(300, 12);
        assert_ne!(a, c, "{name}: different seed must differ");
        for w in a.requests.windows(2) {
            assert!(w[1].arrival >= w[0].arrival, "{name}: arrivals unsorted");
        }
    }
}

#[test]
fn unknown_scenario_error_lists_the_registry() {
    let reg = ScenarioRegistry::with_builtins();
    let err = reg
        .build("bogus", &ExperimentConfig::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown scenario `bogus`"), "{err}");
    assert!(err.contains("bursty_mixed"), "{err}");
    assert!(err.contains("stationary"), "{err}");
}

#[test]
fn bursty_and_diurnal_traces_reproduce_their_mean_rps() {
    // distribution-shape coverage: realized rate over a long trace must
    // match the configured long-run mean within tolerance
    // bursty tolerance is wide: MMPP phase durations are exponential, so
    // the realized rate of one deterministic trace carries ~5% rel. std
    for (name, tol_frac) in [("bursty_mixed", 0.20), ("diurnal_chat", 0.10)] {
        let exp = base_exp(2.0, 3);
        let spec = ScenarioRegistry::with_builtins()
            .build(name, &exp)
            .unwrap();
        let mean = spec.arrival.mean_rps();
        assert!(
            (mean - 2.0).abs() < 1e-9,
            "{name}: builders must preserve cluster.rps as the mean (got {mean})"
        );
        let mut rng = Pcg64::new(17, 29);
        let times = spec.arrival.sample(25_000, &mut rng);
        let realized = times.len() as f64 / times.last().unwrap();
        assert!(
            (realized - mean).abs() < tol_frac * mean,
            "{name}: realized rate {realized:.3} vs configured mean {mean:.3}"
        );
    }
}

#[test]
fn onoff_burstiness_exceeds_poisson() {
    let exp = base_exp(2.0, 3);
    let spec = ScenarioRegistry::with_builtins()
        .build("bursty_mixed", &exp)
        .unwrap();
    let mut rng = Pcg64::new(5, 5);
    let times = spec.arrival.sample(20_000, &mut rng);
    let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
    let n = gaps.len() as f64;
    let mean = gaps.iter().sum::<f64>() / n;
    let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / n;
    let cv = var.sqrt() / mean;
    assert!(
        cv > 1.2,
        "bursty_mixed inter-arrival CV {cv:.2} should exceed the Poisson value 1.0"
    );
    // and the stationary baseline should sit near 1.0
    let stat = ScenarioRegistry::with_builtins()
        .build("stationary", &exp)
        .unwrap();
    let mut rng = Pcg64::new(5, 5);
    let times = stat.arrival.sample(20_000, &mut rng);
    let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
    let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
    let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
    let cv0 = var.sqrt() / mean;
    assert!((cv0 - 1.0).abs() < 0.1, "poisson CV {cv0:.2}");
}

#[test]
fn session_turns_never_arrive_before_prior_turn_completes() {
    let mut exp = base_exp(0.4, 21);
    exp.scenario_name = Some("multi_round".to_string());
    let spec = resolve_scenario(&exp).unwrap().expect("named scenario");
    let strace = spec.generate(60, exp.cluster.seed);
    assert!(strace.sessions.total_follow_ups() > 0, "need follow-ups");
    let planned = strace.total_planned();
    let params = SimParams {
        exp,
        ..Default::default()
    };
    let report = Simulator::with_scenario(
        params,
        strace,
        &star::coordinator::PolicyRegistry::with_builtins(),
    )
    .unwrap()
    .run();
    assert_eq!(report.n_failed, 0, "roomy capacity: nothing may fail");
    assert_eq!(report.completed.len(), planned);
    let by_id: std::collections::HashMap<_, _> =
        report.completed.iter().map(|l| (l.id, l)).collect();
    let mut checked = 0;
    for chain in &report.session_chains {
        for w in chain.windows(2) {
            let prev = by_id[&w[0]];
            let next = by_id[&w[1]];
            assert!(
                next.arrival >= prev.finished.unwrap() - 1e-9,
                "turn {} arrived at {:.3} before turn {} finished at {:.3}",
                w[1],
                next.arrival,
                w[0],
                prev.finished.unwrap()
            );
            checked += 1;
        }
    }
    assert!(checked > 0, "no realized multi-turn chains");
}

#[test]
fn bursty_mixed_sim_reports_per_class_percentiles_and_goodput() {
    // the acceptance-criteria path: bursty_mixed end-to-end with per-class
    // TTFT/TPOT percentiles and per-class goodput in the report
    let mut exp = base_exp(0.5, 9);
    exp.scenario_name = Some("bursty_mixed".to_string());
    let spec = resolve_scenario(&exp).unwrap().expect("named scenario");
    let strace = spec.generate(150, exp.cluster.seed);
    let slos = spec.slos();
    let report = run_scenario_trace(
        star::bench::scenarios::paper_scenarios()[3], // STAR Oracle
        exp,
        false,
        &strace,
    );
    assert!(report.completed.len() > 100);
    let per_class = report.class_metrics(&slos);
    assert!(
        per_class.len() >= 2,
        "mixed workload must surface multiple classes: {per_class:?}"
    );
    for c in &per_class {
        assert!(c.n > 0);
        assert!(c.ttft_p50_ms.is_finite() && c.ttft_p50_ms > 0.0);
        assert!(c.ttft_p99_ms >= c.ttft_p50_ms - 1e-9);
        assert!(c.goodput >= 0.0);
    }
    let summary = report.class_summary(&slos);
    for c in &per_class {
        assert!(
            summary.contains(c.class.name()),
            "summary must mention {}: {summary}",
            c.class.name()
        );
    }
    // per-class goodput must differ from judging everything on one SLO
    // whenever relaxed-SLO classes have violations of the strict target
    let m = report.metrics();
    assert!(m.goodput_by_class(&slos) >= 0.0);
}

#[test]
fn classes_flow_from_trace_to_completed_latencies() {
    let mut exp = base_exp(0.5, 13);
    exp.scenario_name = Some("bursty_mixed".to_string());
    let spec = resolve_scenario(&exp).unwrap().unwrap();
    let strace = spec.generate(120, exp.cluster.seed);
    let expect: std::collections::HashMap<u64, RequestClass> = strace
        .requests
        .iter()
        .map(|r| (r.id, r.class))
        .collect();
    let params = SimParams {
        exp,
        ..Default::default()
    };
    let report = Simulator::with_scenario(
        params,
        strace,
        &star::coordinator::PolicyRegistry::with_builtins(),
    )
    .unwrap()
    .run();
    assert!(!report.completed.is_empty());
    for l in &report.completed {
        assert_eq!(
            l.class, expect[&l.id],
            "latency {} lost its class label",
            l.id
        );
    }
}

#[test]
fn rebuild_scenario_tracks_cluster_overrides() {
    // [workload.*] table defaults derive from cluster.rps; a CLI --rps
    // applied after config parse must flow into the rebuilt scenario
    let cfg = star::config::Config::from_str("[workload.arrival]\nkind = \"onoff\"\n").unwrap();
    let mut exp = ExperimentConfig::from_config(&cfg).unwrap();
    let frozen = exp.scenario.as_ref().unwrap().arrival.mean_rps();
    exp.cluster.rps = 2.0; // simulate the CLI override
    exp.rebuild_scenario(&cfg).unwrap();
    let rebuilt = exp.scenario.as_ref().unwrap().arrival.mean_rps();
    assert!((rebuilt - 2.0).abs() < 1e-9, "rebuilt mean {rebuilt}");
    assert!(
        (frozen - rebuilt).abs() > 1e-9,
        "test must actually change the rate (frozen {frozen})"
    );
}

#[test]
fn replay_arrival_process_round_trips_through_config() {
    let path = std::env::temp_dir().join("star_scenarios_replay.txt");
    std::fs::write(&path, "0.25\n0.5\n1.5\n").unwrap();
    let toml = format!(
        "[workload.arrival]\nkind = \"replay\"\npath = \"{}\"\n",
        path.display()
    );
    let cfg = star::config::Config::from_str(&toml).unwrap();
    let exp = ExperimentConfig::from_config(&cfg).unwrap();
    let spec = resolve_scenario(&exp).unwrap().expect("replay scenario");
    assert_eq!(
        spec.arrival,
        ArrivalProcess::Replay {
            times: vec![0.25, 0.5, 1.5]
        }
    );
    // replay caps the trace length at the recorded series
    let trace = spec.generate(10, 0);
    assert_eq!(trace.requests.len(), 3);
    assert_eq!(trace.requests[2].arrival, 1.5);
    std::fs::remove_file(&path).ok();
}
