//! Fixture: the allowlisted path. The first block is missing its
//! `// SAFETY:` comment (expected R3 finding: line 6); the second is
//! properly commented and must NOT fire.

pub fn no_comment(p: *const u8) -> u8 {
    unsafe { *p }
}

pub fn with_comment(p: *const u8) -> u8 {
    // SAFETY: fixture — caller guarantees p is valid and aligned
    unsafe { *p }
}
