// R6 fixture: a span assembler that handles only three of the four
// TraceEvent variants — `KvSample` is missing on purpose (the `_` arm
// does not count: R6 wants the variant named, so a new event cannot
// silently fall through a catch-all).
pub fn absorb(ev: &TraceEvent) {
    match ev {
        TraceEvent::Arrived { request } => drop(request),
        TraceEvent::PrefillDone { .. } => {}
        TraceEvent::Finished { .. } => {}
        _ => {}
    }
}
