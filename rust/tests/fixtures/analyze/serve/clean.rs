//! Fixture: a clean serve-layer file — zero findings expected. Wall
//! clock is legal here (R2 exempts serve/), `expect` satisfies R4, and
//! identifier substrings / string contents must not trip R1 or R3.

use std::collections::BTreeMap;
use std::time::Instant;

pub fn memory_unsafe_name_is_not_a_keyword() -> &'static str {
    "unsafe HashMap in a string literal is invisible to the lexer"
}

pub fn serve_tick(m: &mut BTreeMap<u64, Instant>) -> Instant {
    let now = Instant::now();
    m.insert(0, now);
    *m.get(&0).expect("inserted above")
}
