// R6 fixture: the trace-event catalog. `KvSample` (line 7) is never
// handled by the fixture span assembler in obs/spans.rs, so R6 must
// report it here, on the variant's own line.
pub enum TraceEvent {
    Arrived { request: u64 },
    PrefillDone { request: u64, instance: usize },
    KvSample { instance: usize, kv_frac: f64 },
    Finished { request: u64, instance: usize },
}
