//! Fixture: wall-clock leaks in the simulated core. Expected findings:
//!   R2 at the `SystemTime` use (line 7) and the call (line 10)
//!   R2 at the `Instant::now` call (line 16); line 15's un-called
//!     `Instant` type mention must NOT fire
//! The waived HashSet (line 21) must NOT fire.

use std::time::SystemTime;

pub fn wall_seed() -> u64 {
    match SystemTime::now().elapsed() {
        _ => 0,
    }
}

pub fn measure(at: std::time::Instant) -> std::time::Duration {
    at.elapsed() + std::time::Instant::now().elapsed()
}

pub fn waived_set() -> usize {
    // ANALYZE-OK: R1 fixture waiver — built and drained, never iterated
    let s: std::collections::HashSet<u32> = Default::default();
    s.len()
}
