//! Fixture: `unsafe` outside the R3 allowlist (expected finding: line 5).

pub fn peek(p: *const u8) -> u8 {
    // SAFETY: a comment alone does not move a file onto the allowlist
    unsafe { *p }
}
