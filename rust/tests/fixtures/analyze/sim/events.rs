//! Fixture: a mini event enum. `Finish` is deliberately neither matched
//! in the fixture engine nor listed in its VALIDATED_EVENTS — R5 must
//! flag it twice (once per missing surface).

pub enum Event {
    Tick,
    Arrive { id: u64 },
    Finish(u64),
}
