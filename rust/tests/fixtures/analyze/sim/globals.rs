//! Fixture: shared mutable globals in the sharded simulation core.
//! Expected findings:
//!   R7 at the `static mut` (line 8), the OnceLock static (line 10),
//!   and the Atomic static (line 12); the waived Mutex static (line 15)
//!   and the #[cfg(test)] static mut (line 19) must NOT fire.

/// A per-process counter the sharded engine must never keep.
pub static mut STEP_COUNTER: u64 = 0;

pub static SHARD_TABLE: std::sync::OnceLock<Vec<u64>> = std::sync::OnceLock::new();

pub static MERGES: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

// ANALYZE-OK: R7 fixture waiver — exercises the waiver path
pub static WAIVED: std::sync::Mutex<u64> = std::sync::Mutex::new(0);

#[cfg(test)]
mod tests {
    pub static mut SCRATCH: u64 = 0;
}
