//! Fixture: known-bad engine. Expected findings (tests/analyze.rs pins
//! the exact lines):
//!   R1 at the `use` (line 8) and the signature (line 13)
//!   R4 at the bare unwrap (line 14)
//!   R5 at the VALIDATED_EVENTS const (line 11): `Finish` not listed
//! The test module at the bottom must produce NO findings.

use std::collections::HashMap;

// the fixture coverage list omits `Finish`
pub const VALIDATED_EVENTS: &[&str] = &["Tick", "Arrive"];

pub fn step(m: &mut HashMap<u64, u64>, ev: Event) -> u64 {
    let v = *m.get(&0).unwrap();
    match ev {
        Event::Tick => v,
        Event::Arrive { id } => id,
        _ => 0, // `Event::Finish` is never matched -> R5
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap; // test code: R1 must NOT fire

    #[test]
    fn unwrap_in_tests_is_fine() {
        let m: HashMap<u64, u64> = HashMap::new();
        assert!(m.get(&0).copied().unwrap_or(0) == 0);
        let x: Option<u32> = Some(1);
        x.unwrap(); // test code: R4 must NOT fire
    }
}
