//! Property-based tests over the coordinator invariants (proptest
//! substitute: `star::prop`, seeded + shrink-lite; see DESIGN.md §1).

use star::config::ReschedulerConfig;
use star::coordinator::{
    ClusterSnapshot, ClusterState, IncomingRequest, InstanceView, PolicyConfig, PolicyRegistry,
    Prediction, RequestView, Rescheduler,
};
use star::costmodel::MigrationCostModel;
use star::kvcache::KvCacheManager;
use star::prop::{prop_assert, property, Gen};

fn random_snapshot(g: &mut Gen) -> ClusterSnapshot {
    let n_inst = g.usize(2, 6);
    let mut next_id = 0u64;
    let instances = (0..n_inst)
        .map(|id| {
            let n_req = g.usize(0, g.size.min(12));
            let requests = (0..n_req)
                .map(|_| {
                    next_id += 1;
                    RequestView {
                        id: next_id,
                        tokens: g.u64(1, 8_000),
                        predicted_remaining: if g.bool() {
                            Some(Prediction::exact(g.f64(0.0, 30_000.0)))
                        } else {
                            None
                        },
                        migrating: g.rng().coin(0.1),
                    }
                })
                .collect();
            InstanceView {
                id,
                requests,
                kv_capacity_tokens: g.u64(20_000, 200_000),
                inbound_reserved_tokens: g.u64(0, 5_000),
                cached_tokens: g.u64(0, 5_000),
                lifecycle: Default::default(),
                hardware: Default::default(),
            }
        })
        .collect();
    ClusterSnapshot {
        instances,
        tokens_per_interval: g.f64(1.0, 200.0),
    }
}

fn rescheduler(g: &mut Gen, use_pred: bool) -> Rescheduler {
    let cfg = ReschedulerConfig {
        theta: g.f64(0.0, 0.5),
        horizon: g.usize(1, 12),
        beta_decay: g.f64(0.1, 1.0),
        max_migrations_per_interval: g.usize(1, 3),
        ..Default::default()
    };
    let mig = MigrationCostModel {
        bandwidth_bps: g.f64(1e6, 1e12),
        latency_s: g.f64(0.0, 0.05),
        bytes_per_token: g.u64(16, 1 << 17),
    };
    let mut rs = Rescheduler::new(cfg, mig, use_pred);
    rs.avg_iter_s = g.f64(0.001, 0.05);
    rs
}

#[test]
fn decisions_reference_real_requests_and_distinct_instances() {
    property("decision validity", 300, |g| {
        let snap = random_snapshot(g);
        let use_pred = g.bool();
        let mut rs = rescheduler(g, use_pred);
        for d in rs.decide(&snap.view()) {
            prop_assert(d.src != d.dst, "src == dst")?;
            let src = snap
                .instances
                .iter()
                .find(|i| i.id == d.src)
                .ok_or("src instance missing")?;
            let req = src
                .requests
                .iter()
                .find(|r| r.id == d.request)
                .ok_or("migrated request not on src")?;
            prop_assert(!req.migrating, "picked an already-migrating request")?;
            prop_assert(req.tokens == d.kv_tokens, "kv_tokens mismatch")?;
            prop_assert(d.var_reduction > 0.0, "non-positive reduction")?;
        }
        Ok(())
    });
}

#[test]
fn migration_respects_target_capacity() {
    property("memory safety", 300, |g| {
        let snap = random_snapshot(g);
        let mut rs = rescheduler(g, true);
        for d in rs.decide(&snap.view()) {
            let dst = snap.instances.iter().find(|i| i.id == d.dst).unwrap();
            // at minimum, the moved request's current KV plus the target's
            // current usage must fit the target's capacity
            prop_assert(
                dst.effective_used() + d.kv_tokens <= dst.kv_capacity_tokens,
                format!(
                    "target {} would hold {} / {}",
                    d.dst,
                    dst.effective_used() + d.kv_tokens,
                    dst.kv_capacity_tokens
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn migration_reduces_current_variance_when_prediction_off() {
    property("no-pred variance reduction", 200, |g| {
        let snap = random_snapshot(g);
        let mut rs = rescheduler(g, false);
        let before = snap.current_variance();
        for d in rs.decide(&snap.view()) {
            // replay the move on plain token loads
            let mut loads: Vec<f64> = snap
                .instances
                .iter()
                .map(|i| i.token_load() as f64)
                .collect();
            loads[d.src] -= d.kv_tokens as f64;
            loads[d.dst] += d.kv_tokens as f64;
            let after = star::metrics::snapshot_variance(&loads);
            prop_assert(
                after < before + 1e-6,
                format!("variance went up: {before} -> {after}"),
            )?;
            // only validate the first decision against the original state
            break;
        }
        Ok(())
    });
}

#[test]
fn balanced_clusters_are_left_alone() {
    property("no gratuitous migration", 200, |g| {
        // identical instances => nothing to do regardless of parameters
        let n = g.usize(2, 8);
        let tokens = g.u64(100, 10_000);
        let rem = g.f64(10.0, 10_000.0);
        let instances = (0..n)
            .map(|id| InstanceView {
                id,
                requests: vec![RequestView {
                    id: id as u64 + 1,
                    tokens,
                    predicted_remaining: Some(Prediction::exact(rem)),
                    migrating: false,
                }],
                kv_capacity_tokens: 1_000_000,
                inbound_reserved_tokens: 0,
                cached_tokens: 0,
                lifecycle: Default::default(),
                hardware: Default::default(),
            })
            .collect();
        let snap = ClusterSnapshot {
            instances,
            tokens_per_interval: g.f64(1.0, 100.0),
        };
        let mut rs = rescheduler(g, true);
        prop_assert(rs.decide(&snap.view()).is_empty(), "migrated on a balanced cluster")
    });
}

#[test]
fn dispatcher_always_returns_valid_instance() {
    let registry = PolicyRegistry::with_builtins();
    property("dispatch validity", 300, |g| {
        let snap = random_snapshot(g);
        let name = *g
            .rng()
            .choose(&[
                "round_robin",
                "current_load",
                "predicted_load",
                "slo_aware",
                "session_affinity",
            ]);
        let mut d = registry
            .build_dispatch(name, &PolicyConfig::default())
            .map_err(|e| e.to_string())?;
        for req_id in 0..5u64 {
            let incoming = IncomingRequest {
                id: req_id,
                tokens: g.u64(1, 2_000),
                predicted_remaining: Some(Prediction::exact(g.f64(0.0, 1_000.0))),
                // random (possibly out-of-range) preferences: the policy
                // must still return a valid instance
                preferred_instance: g.bool().then(|| g.usize(0, 8)),
            };
            let id = d.choose(&snap.view(), &incoming);
            prop_assert(
                snap.instances.iter().any(|i| i.id == id),
                "returned unknown instance",
            )?;
        }
        Ok(())
    });
}

#[test]
fn round_robin_is_fair_on_uniform_clusters() {
    property("round robin fairness", 100, |g| {
        let n = g.usize(2, 8);
        let snap = ClusterSnapshot {
            instances: (0..n)
                .map(|id| InstanceView {
                    id,
                    requests: vec![],
                    kv_capacity_tokens: 1_000_000,
                    inbound_reserved_tokens: 0,
                    cached_tokens: 0,
                    lifecycle: Default::default(),
                    hardware: Default::default(),
                })
                .collect(),
            tokens_per_interval: 10.0,
        };
        let mut d = PolicyRegistry::with_builtins()
            .build_dispatch("round_robin", &PolicyConfig::default())
            .map_err(|e| e.to_string())?;
        let rounds = g.usize(1, 6);
        let mut counts = vec![0usize; n];
        for _ in 0..rounds * n {
            let incoming = IncomingRequest {
                id: 0,
                tokens: 10,
                predicted_remaining: None,
                preferred_instance: None,
            };
            counts[d.choose(&snap.view(), &incoming)] += 1;
        }
        prop_assert(
            counts.iter().all(|&c| c == rounds),
            format!("unfair counts {counts:?}"),
        )
    });
}

#[test]
fn cluster_state_reservation_accounting_under_concurrent_migrations() {
    // random interleavings of admission, decode progress, reprediction,
    // release, and (possibly several concurrent) migrations: the
    // incremental aggregates must equal a shadow model recomputed from
    // scratch after every single operation
    property("reservation accounting", 150, |g| {
        let n_inst = g.usize(2, 6);
        let mut st = ClusterState::new(n_inst, 1_000_000, 1.0, 0.02, 1e-6);
        // shadow model: (id, instance, tokens, predicted)
        let mut active: Vec<(u64, usize, u64, Option<f64>)> = Vec::new();
        // in-flight migrations: (id, dst, tokens, predicted)
        let mut inflight: Vec<(u64, usize, u64, Option<f64>)> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..g.usize(1, 100) {
            match g.usize(0, 5) {
                0 | 1 => {
                    next_id += 1;
                    let di = g.usize(0, n_inst - 1);
                    let tokens = g.u64(1, 4_000);
                    let pred = g.bool().then(|| g.f64(0.0, 10_000.0));
                    st.admit(di, next_id, tokens, pred.map(Prediction::exact));
                    active.push((next_id, di, tokens, pred));
                }
                2 => {
                    if !active.is_empty() {
                        let i = g.usize(0, active.len() - 1);
                        st.append_token(active[i].0);
                        active[i].2 += 1;
                    }
                }
                3 => {
                    if !active.is_empty() {
                        let i = g.usize(0, active.len() - 1);
                        let pred = g.bool().then(|| g.f64(0.0, 10_000.0));
                        st.set_prediction(active[i].0, pred.map(Prediction::exact));
                        active[i].3 = pred;
                    }
                }
                4 => {
                    if !active.is_empty() {
                        let i = g.usize(0, active.len() - 1);
                        let (id, src, tokens, pred) = active.swap_remove(i);
                        let dst = (src + g.usize(1, n_inst - 1)) % n_inst;
                        let reserved = st
                            .begin_migration(id, dst)
                            .ok_or_else(|| "migration source untracked".to_string())?;
                        prop_assert(
                            reserved == tokens,
                            "reservation != current KV footprint",
                        )?;
                        inflight.push((id, dst, tokens, pred));
                    }
                }
                _ => {
                    if !inflight.is_empty() && g.bool() {
                        let i = g.usize(0, inflight.len() - 1);
                        let (id, dst, tokens, pred) = inflight.swap_remove(i);
                        st.finish_migration(dst, tokens);
                        // delivery re-admits on the reserved destination
                        st.admit(dst, id, tokens, pred.map(Prediction::exact));
                        active.push((id, dst, tokens, pred));
                    } else if !active.is_empty() {
                        let i = g.usize(0, active.len() - 1);
                        let (id, _, _, _) = active.swap_remove(i);
                        st.release(id);
                    }
                }
            }
            for di in 0..n_inst {
                let s = st.stats(di);
                let want_reserved: u64 =
                    inflight.iter().filter(|m| m.1 == di).map(|m| m.2).sum();
                prop_assert(
                    s.inbound_reserved_tokens() == want_reserved,
                    format!(
                        "instance {di}: inbound {} != shadow {want_reserved}",
                        s.inbound_reserved_tokens()
                    ),
                )?;
                let want_load: u64 = active.iter().filter(|r| r.1 == di).map(|r| r.2).sum();
                prop_assert(
                    s.token_load() == want_load,
                    format!("instance {di}: load {} != shadow {want_load}", s.token_load()),
                )?;
                let want_batch = active.iter().filter(|r| r.1 == di).count();
                prop_assert(
                    s.batch_size() == want_batch,
                    format!("instance {di}: batch {} != shadow {want_batch}", s.batch_size()),
                )?;
                let want_work: f64 = want_load as f64
                    + active
                        .iter()
                        .filter(|r| r.1 == di)
                        .map(|r| r.3.unwrap_or(0.0))
                        .sum::<f64>();
                prop_assert(
                    (s.predicted_work() - want_work).abs() <= 1e-6 * want_work.abs().max(1.0),
                    format!(
                        "instance {di}: predicted work {} != shadow {want_work}",
                        s.predicted_work()
                    ),
                )?;
            }
            // the compatibility materialization must agree with the state
            let snap = st.snapshot();
            match st.consistency_diff(&snap) {
                None => {}
                Some(d) => return Err(format!("state/materialization mismatch: {d}")),
            }
        }
        Ok(())
    });
}

#[test]
fn kv_manager_conserves_blocks() {
    property("kv block conservation", 300, |g| {
        let block = 16u32;
        let cap_tokens = g.u64(10, 500) * block as u64;
        let mut m = KvCacheManager::new(cap_tokens, block);
        let total_blocks = (cap_tokens / block as u64) as usize;
        let mut live: Vec<u64> = Vec::new();
        let mut next = 0u64;
        for _ in 0..g.usize(1, 80) {
            match g.usize(0, 2) {
                0 => {
                    next += 1;
                    let t = g.u64(1, 200);
                    if m.admit(next, t, 0).is_ok() {
                        live.push(next);
                    }
                }
                1 => {
                    if let Some(&id) = live.first() {
                        let _ = m.append_token(id, 0);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = g.usize(0, live.len() - 1);
                        let id = live.swap_remove(idx);
                        m.release(id);
                    }
                }
            }
            // invariant: used + free == capacity, usage within [0,1]
            let used_blocks = (m.usage_frac() * total_blocks as f64).round() as u64;
            prop_assert(
                used_blocks <= total_blocks as u64,
                "used more blocks than capacity",
            )?;
            prop_assert(
                m.free_tokens() <= cap_tokens,
                "free tokens exceed capacity",
            )?;
            prop_assert(
                m.used_tokens() <= cap_tokens,
                "stored tokens exceed capacity",
            )?;
        }
        // release everything: must return to a full pool
        for id in live {
            m.release(id);
        }
        prop_assert(m.free_tokens() == cap_tokens, "leak after releasing all")
    });
}

#[test]
fn percentiles_are_monotone_and_bounded() {
    property("percentile sanity", 200, |g| {
        let vals = g.vec_f64(-1e6, 1e6);
        if vals.is_empty() {
            return Ok(());
        }
        let mut p = star::metrics::Percentiles::new();
        for &v in &vals {
            p.record(v);
        }
        let qs = [0.0, 0.25, 0.5, 0.9, 0.99, 1.0];
        let mut prev = f64::NEG_INFINITY;
        let (mn, mx) = vals
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), &v| {
                (a.min(v), b.max(v))
            });
        for q in qs {
            let x = p.quantile(q);
            prop_assert(x >= prev - 1e-9, "quantile not monotone")?;
            prop_assert(x >= mn - 1e-9 && x <= mx + 1e-9, "quantile out of range")?;
            prev = x;
        }
        Ok(())
    });
}

#[test]
fn config_parser_roundtrips_random_flat_configs() {
    property("toml-subset roundtrip", 200, |g| {
        let n = g.usize(1, 12);
        let mut text = String::from("[s]\n");
        let mut expect = Vec::new();
        for i in 0..n {
            let key = format!("k{i}");
            match g.usize(0, 2) {
                0 => {
                    let v = g.u64(0, 1_000_000) as i64 - 500_000;
                    text.push_str(&format!("{key} = {v}\n"));
                    expect.push((key, format!("{v}")));
                }
                1 => {
                    let v = (g.f64(-1e3, 1e3) * 100.0).round() / 100.0;
                    text.push_str(&format!("{key} = {v:?}\n"));
                    expect.push((key, format!("{v}")));
                }
                _ => {
                    let v = format!("str{}", g.u64(0, 999));
                    text.push_str(&format!("{key} = \"{v}\"\n"));
                    expect.push((key, v));
                }
            }
        }
        let cfg = star::config::Config::from_str(&text).map_err(|e| e.to_string())?;
        for (key, want) in expect {
            let path = format!("s.{key}");
            let got = cfg
                .get(&path)
                .ok_or_else(|| format!("missing {path}"))?;
            let got_s = match got {
                star::config::Value::Int(i) => format!("{i}"),
                star::config::Value::Float(f) => format!("{f}"),
                star::config::Value::Str(s) => s.clone(),
                other => format!("{other:?}"),
            };
            prop_assert(got_s == want, format!("{path}: {got_s} != {want}"))?;
        }
        Ok(())
    });
}
