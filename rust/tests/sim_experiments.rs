//! Integration tests over the simulator: the paper's directional claims
//! must hold on small randomized workloads (these are the invariants the
//! benches then quantify).

use star::bench::scenarios::{paper_scenarios, run_scenario, small_cluster, trace_for};
use star::metrics::Slo;
use star::prop::{prop_assert, property};
use star::sim::{SimParams, Simulator, StateMode};
use star::workload::{Dataset, TraceGen};

#[test]
fn rescheduling_reduces_exec_variance_on_small_cluster() {
    let exp = small_cluster(Dataset::ShareGpt, 0.12, 3);
    let trace = trace_for(&exp, 150);
    let scs = paper_scenarios();
    let vllm = run_scenario(scs[0], exp.clone(), false, &trace);
    let star = run_scenario(scs[3], exp, false, &trace); // oracle
    assert!(
        star.exec_var.sample_mean() < vllm.exec_var.sample_mean() * 0.6,
        "oracle STAR should cut exec variance strongly: {} vs {}",
        star.exec_var.sample_mean(),
        vllm.exec_var.sample_mean()
    );
    assert!(star.migrations > 0);
}

#[test]
fn rescheduling_improves_tail_latency_under_load() {
    // the KV-bound equilibrium regime (DESIGN.md §5): 8 H800-profile
    // decode instances at ~0.5 rps — the regime the paper's Fig. 10
    // large-cluster numbers live in
    let mut exp = small_cluster(Dataset::ShareGpt, 0.5, 9);
    exp.cluster.n_decode = 8;
    exp.cluster.n_prefill = 2;
    exp.cluster.kv_capacity_tokens = 160_000;
    exp.cluster.max_batch = 64;
    let trace = trace_for(&exp, 200);
    let scs = paper_scenarios();
    let vllm = run_scenario(scs[0], exp.clone(), true, &trace);
    let star = run_scenario(scs[2], exp, true, &trace);
    let (v, s) = (vllm.metrics().p99_tpot_ms(), star.metrics().p99_tpot_ms());
    assert!(
        s < v,
        "STAR w/ pred should improve P99 TPOT under load: {s:.2} vs {v:.2} ms"
    );
    assert!(
        star.oom_events <= vllm.oom_events,
        "STAR must not OOM more: {} vs {}",
        star.oom_events,
        vllm.oom_events
    );
}

#[test]
fn goodput_never_exceeds_throughput() {
    property("goodput <= throughput", 25, |g| {
        let rps = g.f64(0.05, 0.2);
        let seed = g.u64(0, 1 << 30);
        let exp = small_cluster(Dataset::ShareGpt, rps, seed);
        let trace = trace_for(&exp, 60);
        let sc = *g.rng().choose(&paper_scenarios());
        let report = run_scenario(sc, exp, false, &trace);
        let m = report.metrics();
        prop_assert(
            m.goodput(Slo::default()) <= m.throughput() + 1e-9,
            "goodput exceeded throughput",
        )
    });
}

#[test]
fn token_conservation_across_policies_and_seeds() {
    property("token conservation", 12, |g| {
        let rps = g.f64(0.1, 0.6);
        let seed = g.u64(0, 1 << 30);
        let mut exp = small_cluster(Dataset::ShareGpt, rps, seed);
        exp.cluster.kv_capacity_tokens = 300_000; // roomy: no failures
        let trace = TraceGen::new(Dataset::ShareGpt, rps).generate(40, seed);
        let sc = *g.rng().choose(&paper_scenarios());
        let report = run_scenario(sc, exp, g.bool(), &trace);
        let done: u64 = report
            .completed
            .iter()
            .map(|l| l.output_tokens as u64)
            .sum();
        let want: u64 = trace.iter().map(|r| r.output_len as u64).sum();
        prop_assert(
            done == want && report.n_failed == 0,
            format!("generated {done} of {want}, failed {}", report.n_failed),
        )
    });
}

#[test]
fn migrated_requests_complete_correctly() {
    // force heavy migration and confirm every request still produces its
    // exact trace-specified output
    let mut exp = small_cluster(Dataset::ShareGpt, 0.2, 77);
    exp.rescheduler.enabled = true;
    exp.rescheduler.interval_s = 0.4;
    exp.predictor = "oracle".to_string();
    let trace = trace_for(&exp, 120);
    let report = Simulator::new(
        SimParams {
            exp,
            ..Default::default()
        },
        &trace,
    )
    .run();
    assert!(report.migrations > 5, "expected heavy migration activity");
    let migrated: Vec<_> = report
        .completed
        .iter()
        .filter(|l| l.migrations > 0)
        .collect();
    assert!(!migrated.is_empty());
    let done: u64 = report.completed.iter().map(|l| l.output_tokens as u64).sum();
    let want: u64 = trace.iter().map(|r| r.output_len as u64).sum();
    assert_eq!(done, want, "migration must not lose or duplicate tokens");
}

#[test]
fn binned_predictors_interpolate_between_none_and_oracle() {
    let mut results = Vec::new();
    for kind in ["none", "binned2", "binned6", "oracle"] {
        let mut exp = small_cluster(Dataset::ShareGpt, 0.13, 21);
        exp.predictor = kind.to_string();
        exp.rescheduler.enabled = true;
        let trace = trace_for(&exp, 150);
        let report = Simulator::new(
            SimParams {
                exp,
                ..Default::default()
            },
            &trace,
        )
        .run();
        results.push((kind, report.exec_var.sample_mean()));
    }
    // ordering claim (Table 3): finer prediction should not be much worse
    // than coarser; oracle should be at least as good as no prediction
    let none = results[0].1;
    let oracle = results[3].1;
    assert!(
        oracle <= none * 1.25,
        "oracle ({oracle:.2}) should not lose badly to none ({none:.2})"
    );
}

#[test]
fn memory_pressure_rescheduler_cuts_ooms_under_tight_memory() {
    // equal config, tight KV memory: the projected-OOM rescheduler must
    // produce fewer OOM events than running with no rescheduling at all,
    // and every request must terminate either way (the stranded-request
    // guard: rescheduling + OOM recompute combined must not leak requests)
    let mk = |reschedule: &str, enabled: bool, seed: u64| {
        let mut exp = small_cluster(Dataset::ShareGpt, 1.2, seed);
        exp.cluster.kv_capacity_tokens = 30_000; // tight
        exp.predictor = "oracle".to_string();
        exp.rescheduler.enabled = enabled;
        exp.rescheduler.interval_s = 0.5;
        exp.reschedule_policy = reschedule.to_string();
        let trace = trace_for(&exp, 60);
        let params = SimParams {
            exp,
            validate_state: true,
            ..Default::default()
        };
        (Simulator::new(params, &trace).run(), trace.len())
    };
    let (mut ooms_none, mut ooms_mp) = (0u64, 0u64);
    for seed in [3u64, 11, 19] {
        let (none, n_none) = mk("none", false, seed);
        let (mp, n_mp) = mk("memory_pressure", true, seed);
        ooms_none += none.oom_events;
        ooms_mp += mp.oom_events;
        assert_eq!(
            none.completed.len() + none.n_failed,
            n_none,
            "seed {seed}: baseline leaked requests"
        );
        assert_eq!(
            mp.completed.len() + mp.n_failed,
            n_mp,
            "seed {seed}: rescheduling + OOM recompute leaked requests"
        );
    }
    assert!(ooms_none > 0, "baseline must actually hit OOMs");
    assert!(
        ooms_mp < ooms_none,
        "memory_pressure should cut OOMs: {ooms_mp} vs {ooms_none}"
    );
}

#[test]
fn all_requests_terminate_under_rescheduling_and_oom() {
    // the combined stress: STAR rescheduling, migrations, OOM recompute
    // cascades, and admission-watermark rejections — completed + failed
    // must exactly cover the trace before the sim-time cap
    for seed in [1u64, 7, 23] {
        let mut exp = small_cluster(Dataset::ShareGpt, 1.5, seed);
        exp.cluster.kv_capacity_tokens = 35_000;
        exp.predictor = "oracle".to_string();
        exp.rescheduler.enabled = true;
        exp.rescheduler.interval_s = 0.5;
        let trace = trace_for(&exp, 80);
        let params = SimParams {
            exp,
            ..Default::default()
        };
        let report = Simulator::new(params, &trace).run();
        assert_eq!(
            report.completed.len() + report.n_failed,
            80,
            "seed {seed}: request leaked (completed {} + failed {})",
            report.completed.len(),
            report.n_failed
        );
        assert!(
            report.duration < params_cap(),
            "seed {seed}: sim ran to the time cap instead of terminating"
        );
    }
}

fn params_cap() -> f64 {
    SimParams::default().max_sim_time
}

#[test]
fn incremental_state_matches_rebuild_under_full_stress() {
    // differential acceptance: incremental ClusterState equals the
    // from-scratch snapshot after EVERY event (validate_state), and the
    // RebuildPerDecision compatibility mode takes the identical trajectory
    let mut exp = small_cluster(Dataset::ShareGpt, 1.2, 5);
    exp.cluster.kv_capacity_tokens = 40_000;
    exp.predictor = "oracle".to_string();
    exp.rescheduler.enabled = true;
    exp.rescheduler.interval_s = 0.5;
    let trace = trace_for(&exp, 70);
    let incremental = SimParams {
        exp,
        validate_state: true,
        ..Default::default()
    };
    let mut rebuild = incremental.clone();
    rebuild.state_mode = StateMode::RebuildPerDecision;
    let a = Simulator::new(incremental, &trace).run();
    let b = Simulator::new(rebuild, &trace).run();
    assert_eq!(a.completed.len(), b.completed.len());
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.oom_events, b.oom_events);
    assert!((a.duration - b.duration).abs() < 1e-9);
}

#[test]
fn scheduler_decision_time_stays_bounded() {
    // §5.2 claim at a mid-size cluster: decisions well under 300 ms
    let mut exp = small_cluster(Dataset::ShareGpt, 2.0, 5);
    exp.cluster.n_decode = 64;
    exp.cluster.n_prefill = 8;
    exp.predictor = "oracle".to_string();
    let trace = TraceGen::new(Dataset::ShareGpt, 2.0).generate_for(60.0, 5);
    let report = Simulator::new(
        SimParams {
            exp,
            ..Default::default()
        },
        &trace,
    )
    .run();
    assert!(
        report.scheduler_stats.max_decision_us < 300_000,
        "scheduler took {} us",
        report.scheduler_stats.max_decision_us
    );
}
