//! Live-serving integration tests: the full thread topology (proxy +
//! prefill workers + decode instance threads) over the real PJRT runtime.
//! Skipped when `make artifacts` has not run. Kept small — every decode
//! step is a real HLO execution.

use std::sync::Arc;

use star::runtime::{artifacts_dir, StarRuntime};
use star::serve::{LiveRequest, ServeParams, Server};

fn runtime() -> Option<Arc<StarRuntime>> {
    match artifacts_dir(None) {
        Ok(d) => Some(Arc::new(StarRuntime::load(&d).expect("artifacts load"))),
        Err(_) => {
            eprintln!("SKIP: artifacts not built");
            None
        }
    }
}

fn tiny_request(id: u64, arrival: f64, out: u32, tag: u8) -> LiveRequest {
    LiveRequest {
        id,
        arrival,
        prompt: vec![1, b'Q', b'a' + tag, b'x', b'y', b'?'],
        forced_output: Some(out),
        tag,
        class: star::workload::RequestClass::Chat,
    }
}

#[test]
fn serves_forced_length_requests_to_completion() {
    let Some(rt) = runtime() else { return };
    let mut params = ServeParams::default();
    params.exp.cluster.n_prefill = 1;
    params.exp.cluster.n_decode = 2;
    params.exp.cluster.kv_capacity_tokens = 3_000;
    params.exp.cluster.max_batch = 8;
    params.exp.rescheduler.enabled = true;
    params.exp.rescheduler.interval_s = 0.2;
    params.exp.predictor = "oracle".to_string();
    params.max_wall_s = 120.0;
    let reqs: Vec<LiveRequest> = (0..6)
        .map(|i| tiny_request(i, 0.05 * i as f64, 20 + 10 * (i as u32 % 3), (i % 8) as u8))
        .collect();
    let server = Server::new(rt, params);
    let out = server.run(reqs).expect("serve run");
    assert_eq!(out.metrics.completed.len(), 6, "all requests complete");
    for l in &out.metrics.completed {
        assert!(l.output_tokens >= 20);
        assert!(l.ttft().unwrap() >= 0.0);
        assert!(l.mean_tpot.unwrap() >= 0.0);
        assert!(l.finished.unwrap() >= l.first_token.unwrap());
    }
}

#[test]
fn live_migration_preserves_completion() {
    let Some(rt) = runtime() else { return };
    let mut params = ServeParams::default();
    params.exp.cluster.n_prefill = 1;
    params.exp.cluster.n_decode = 3;
    params.exp.cluster.kv_capacity_tokens = 2_000;
    params.exp.cluster.max_batch = 8;
    params.exp.rescheduler.enabled = true;
    params.exp.rescheduler.interval_s = 0.15;
    params.exp.rescheduler.theta = 0.05; // aggressive: force migrations
    params.exp.predictor = "oracle".to_string();
    params.max_wall_s = 180.0;
    // skew: one very long request plus a crowd of short ones arriving
    // together so one instance overloads
    let mut reqs = vec![tiny_request(0, 0.0, 220, 7)];
    for i in 1..8 {
        reqs.push(tiny_request(i, 0.02 * i as f64, 25, 1));
    }
    let server = Server::new(rt, params);
    let out = server.run(reqs).expect("serve run");
    assert_eq!(out.metrics.completed.len(), 8);
    // completion counts matter more than whether migration fired (timing
    // dependent), but record it for visibility
    eprintln!(
        "live migrations: {}, OOMs: {}",
        out.migrations, out.oom_events
    );
}

#[test]
fn session_follow_up_turns_replay_on_live_path() {
    use star::workload::{RequestClass, SessionPlan, SessionTurn};
    let Some(rt) = runtime() else { return };
    let mut params = ServeParams::default();
    params.exp.cluster.n_prefill = 1;
    params.exp.cluster.n_decode = 2;
    params.exp.cluster.kv_capacity_tokens = 3_000;
    params.exp.cluster.max_batch = 8;
    params.exp.rescheduler.enabled = false;
    params.exp.predictor = "oracle".to_string();
    params.max_wall_s = 120.0;
    // request 0 opens a 2-turn session: the follow-up arrives only after
    // turn 1 completes (plus a short think time) with a grown prompt
    params.sessions = SessionPlan {
        scripts: vec![vec![SessionTurn {
            prompt_len: 24,
            output_len: 15,
            think_time_s: 0.2,
            class: RequestClass::Chat,
            tag: 1,
        }]],
        first_turns: vec![(0, 0)],
    };
    let reqs = vec![tiny_request(0, 0.0, 20, 1), tiny_request(1, 0.05, 20, 1)];
    let server = Server::new(rt, params);
    let out = server.run(reqs).expect("serve run");
    assert_eq!(
        out.metrics.completed.len(),
        3,
        "2 initial + 1 follow-up turn must complete"
    );
    let first = out
        .metrics
        .completed
        .iter()
        .find(|l| l.id == 0)
        .expect("turn 1 completed");
    let follow = out
        .metrics
        .completed
        .iter()
        .find(|l| l.id == 2)
        .expect("follow-up spawned with the next free id");
    assert!(
        follow.arrival >= first.finished.unwrap() + 0.2 - 1e-6,
        "follow-up at {} must wait for turn-1 completion {} + think time",
        follow.arrival,
        first.finished.unwrap()
    );
}

#[test]
fn llm_native_predictor_runs_on_live_path() {
    let Some(rt) = runtime() else { return };
    let mut params = ServeParams::default();
    params.exp.cluster.n_prefill = 1;
    params.exp.cluster.n_decode = 2;
    params.exp.cluster.kv_capacity_tokens = 3_000;
    params.exp.cluster.max_batch = 8;
    params.exp.rescheduler.enabled = true;
    params.exp.predictor = "llm_native".to_string();
    params.exp.rescheduler.predict_every_iters = 5;
    params.max_wall_s = 120.0;
    // EOS-driven generation (no forced length): the real serving mode
    let reqs: Vec<LiveRequest> = (0..4)
        .map(|i| LiveRequest {
            id: i,
            arrival: 0.05 * i as f64,
            prompt: vec![1, b'Q', b'c', b'd', b'e', b'?'],
            forced_output: None,
            tag: 2,
            class: star::workload::RequestClass::Chat,
        })
        .collect();
    let server = Server::new(rt, params);
    let out = server.run(reqs).expect("serve run");
    assert_eq!(
        out.metrics.completed.len(),
        4,
        "EOS-driven requests must terminate"
    );
    for l in &out.metrics.completed {
        assert!(
            l.output_tokens < 512,
            "short-tag request ran to the cap: {}",
            l.output_tokens
        );
    }
}

#[test]
fn elastic_scaling_serves_to_completion() {
    // wiring smoke for the live elastic path: scale ticks fire, the pool
    // timeline is sampled, and every request still completes whether or
    // not the policy decides to flip anything (timing dependent).
    let Some(rt) = runtime() else { return };
    let mut params = ServeParams::default();
    params.exp.cluster.n_prefill = 2;
    params.exp.cluster.n_decode = 2;
    params.exp.cluster.kv_capacity_tokens = 3_000;
    params.exp.cluster.max_batch = 8;
    params.exp.rescheduler.enabled = false;
    params.exp.predictor = "oracle".to_string();
    params.exp.scaling_policy = "queue_pressure".to_string();
    params.exp.elastic.scale_interval_s = 0.25;
    params.exp.elastic.cooldown_s = 0.5;
    params.exp.elastic.flip_delay_s = 0.1;
    params.max_wall_s = 120.0;
    let reqs: Vec<LiveRequest> = (0..8)
        .map(|i| tiny_request(i, 0.03 * i as f64, 20 + 5 * (i as u32 % 3), (i % 8) as u8))
        .collect();
    let server = Server::new(rt, params);
    let out = server.run(reqs).expect("serve run");
    assert_eq!(out.metrics.completed.len(), 8, "no request lost under elasticity");
    assert!(
        !out.pool_timeline.is_empty(),
        "scale ticks must sample the pool"
    );
    for s in &out.pool_timeline {
        assert!(s.prefill_active >= 1 && s.decode_active >= 1, "floors hold");
    }
    eprintln!("live scale actions: {:?}", out.scale_actions);
}
