//! Elastic instance-pool integration tests over the simulator: frozen
//! (`static`) scaling must be inert, scenario × elasticity must be
//! deterministic (same seed ⇒ identical scale-action trace and report),
//! and drain-then-flip must lose no requests while never dispatching
//! onto a draining instance (the engine debug-asserts the dispatch
//! invariant on every hand-off, so these runs prove it by completing).

use star::bench::scenarios::ScenarioRegistry;
use star::config::ExperimentConfig;
use star::coordinator::{ClusterView, PolicyRegistry, PoolStats, ScalingAction, ScalingPolicy};
use star::sim::{SimParams, SimReport, Simulator};

fn exp_for(scenario: &str, n_decode: usize, scaling: &str) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_prefill = 2;
    exp.cluster.n_decode = n_decode;
    exp.cluster.rps = 0.5;
    exp.cluster.n_requests = 100;
    exp.cluster.kv_capacity_tokens = 400_000;
    exp.cluster.seed = 11;
    exp.predictor = "oracle".to_string();
    exp.scenario_name = Some(scenario.to_string());
    exp.scaling_policy = scaling.to_string();
    exp.elastic.scale_interval_s = 2.0;
    exp.elastic.cooldown_s = 2.0;
    exp.elastic.flip_delay_s = 1.0;
    exp
}

fn run(exp: &ExperimentConfig, registry: &PolicyRegistry) -> SimReport {
    let spec = ScenarioRegistry::with_builtins()
        .build(exp.scenario_name.as_deref().unwrap(), exp)
        .expect("builtin scenario");
    let trace = spec.generate(exp.cluster.n_requests, exp.cluster.seed);
    let params = SimParams {
        exp: exp.clone(),
        validate_state: true,
        ..Default::default()
    };
    Simulator::with_scenario(params, trace, registry)
        .expect("simulator construction")
        .run()
}

/// Exact-equality fingerprint of a run (f64 fields compared bitwise —
/// the determinism and static-inertness claims are bit-for-bit).
fn fingerprint(r: &SimReport) -> (u64, usize, usize, u64, u64, u64) {
    let finished_sum: f64 = r.completed.iter().map(|l| l.finished.unwrap()).sum();
    (
        r.duration.to_bits(),
        r.completed.len(),
        r.n_failed,
        r.migrations,
        r.oom_events,
        finished_sum.to_bits(),
    )
}

#[test]
fn static_scaling_is_inert_whatever_the_scale_interval() {
    // under `static` the ScaleTick only samples the timeline; changing
    // its cadence must not perturb the trajectory at all
    let reg = PolicyRegistry::with_builtins();
    let base = run(&exp_for("diurnal_chat", 3, "static"), &reg);
    for interval in [0.5, 7.0] {
        let mut exp = exp_for("diurnal_chat", 3, "static");
        exp.elastic.scale_interval_s = interval;
        let other = run(&exp, &reg);
        assert_eq!(
            fingerprint(&base),
            fingerprint(&other),
            "static scaling must reproduce the frozen-pool run bit-for-bit \
             (scale interval {interval}s)"
        );
    }
    assert!(base.scale_actions.is_empty());
    for s in &base.pool_timeline {
        assert_eq!((s.prefill_active, s.decode_active), (2, 3));
    }
}

#[test]
fn same_seed_means_identical_scale_trace_and_report() {
    // scenario × elasticity determinism (diurnal_chat + predictive):
    // the scale-action trace and the report must match verbatim
    let reg = PolicyRegistry::with_builtins();
    let a = run(&exp_for("diurnal_chat", 3, "predictive"), &reg);
    let b = run(&exp_for("diurnal_chat", 3, "predictive"), &reg);
    assert_eq!(a.scale_actions, b.scale_actions, "scale-action traces differ");
    assert_eq!(a.pool_timeline, b.pool_timeline, "pool timelines differ");
    assert_eq!(fingerprint(&a), fingerprint(&b), "reports differ");
}

/// Scripted scaling policy: flip decode 2 → prefill early in the run,
/// then flip a prefill back → decode later. Conditions are phrased on
/// observed pool state so a guard-rejected proposal is simply re-issued
/// next tick (policies cannot see acceptance directly).
struct ScriptedFlips;

impl ScalingPolicy for ScriptedFlips {
    fn name(&self) -> &str {
        "scripted_flips"
    }

    fn decide(&mut self, _view: &ClusterView<'_>, pool: &PoolStats) -> Vec<ScalingAction> {
        if pool.transition_in_flight() {
            return Vec::new();
        }
        if pool.now >= 2.0 && pool.now < 60.0 && pool.decode_active == 3 {
            return vec![ScalingAction::FlipToPrefill { decode: 2 }];
        }
        if pool.now >= 60.0 && pool.decode_active == 2 && pool.prefill_active == 3 {
            return vec![ScalingAction::FlipToDecode];
        }
        Vec::new()
    }
}

#[test]
fn drain_then_flip_loses_no_requests() {
    let mut reg = PolicyRegistry::with_builtins();
    reg.register_scaling("scripted_flips", |_| Ok(Box::new(ScriptedFlips)));
    let exp = exp_for("diurnal_chat", 3, "scripted_flips");
    let report = run(&exp, &reg);

    // both flips executed, in order
    let flips: Vec<ScalingAction> = report.scale_actions.iter().map(|r| r.action).collect();
    assert_eq!(
        flips,
        vec![
            ScalingAction::FlipToPrefill { decode: 2 },
            ScalingAction::FlipToDecode,
        ],
        "scripted flips must execute exactly once each"
    );

    // no request lost across either flip: every planned request is
    // accounted for, and with this much KV headroom none may fail
    assert_eq!(report.n_failed, 0, "roomy cluster must not fail requests");
    assert_eq!(
        report.completed.len(),
        100,
        "every request must complete across the drain-then-flip cycle"
    );

    // the pool actually changed shape: a sample with the flipped-out
    // decode pool, and a later sample with the flipped-back one
    assert!(
        report
            .pool_timeline
            .iter()
            .any(|s| s.decode_active == 2 && s.prefill_active == 3),
        "timeline never showed the decode→prefill flip: {:?}",
        report.pool_timeline
    );
    let last = report.pool_timeline.last().unwrap();
    assert_eq!(
        (last.prefill_active, last.decode_active),
        (2, 3),
        "pool must return to a 2p/3d shape after the flip back"
    );

    // determinism holds for custom policies too
    let mut reg2 = PolicyRegistry::with_builtins();
    reg2.register_scaling("scripted_flips", |_| Ok(Box::new(ScriptedFlips)));
    let again = run(&exp, &reg2);
    assert_eq!(report.scale_actions, again.scale_actions);
    assert_eq!(fingerprint(&report), fingerprint(&again));
}

#[test]
fn builtin_elastic_policies_run_scenarios_to_completion() {
    let reg = PolicyRegistry::with_builtins();
    for scaling in ["queue_pressure", "predictive"] {
        for scenario in ["bursty_mixed", "diurnal_chat"] {
            let mut exp = exp_for(scenario, 3, scaling);
            exp.cluster.n_requests = 60;
            let report = run(&exp, &reg);
            assert_eq!(
                report.completed.len() + report.n_failed,
                60,
                "{scaling}/{scenario}: requests lost"
            );
            // floors hold at every sample
            for s in &report.pool_timeline {
                assert!(
                    s.prefill_active >= 1 && s.decode_active >= 1,
                    "{scaling}/{scenario}: pool floor violated: {s:?}"
                );
            }
        }
    }
}
