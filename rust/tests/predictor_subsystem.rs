//! Integration tests over the first-class prediction subsystem: the
//! goodput value of predictor quality (sanity ordering), the calibration
//! scorecard's fidelity to the injected noise, run determinism per
//! (seed, predictor), uncertainty-aware quantile aggregates, and
//! third-party predictor registration end-to-end.

use star::bench::scenarios::ScenarioRegistry;
use star::config::ExperimentConfig;
use star::coordinator::{ClusterState, PolicyRegistry, Prediction};
use star::metrics::TraceEvent;
use star::predictor::{LengthPredictor, PredictInput, PredictorRegistry};
use star::sim::{SimParams, SimReport, Simulator};

fn scenario_exp(scenario: &str, predictor: &str, rel_err: f64, seed: u64) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_prefill = 2;
    exp.cluster.n_decode = 6;
    exp.cluster.kv_capacity_tokens = 96_000;
    exp.cluster.max_batch = 48;
    exp.cluster.rps = 0.45;
    exp.cluster.seed = seed;
    exp.rescheduler.enabled = true;
    exp.predictor = predictor.to_string();
    exp.predictor_rel_err = rel_err;
    exp.scenario_name = Some(scenario.to_string());
    exp
}

fn run(exp: &ExperimentConfig, n: usize) -> SimReport {
    let spec = ScenarioRegistry::with_builtins()
        .build(exp.scenario_name.as_deref().unwrap(), exp)
        .expect("builtin scenario");
    let trace = spec.generate(n, exp.cluster.seed);
    let params = SimParams {
        exp: exp.clone(),
        ..Default::default()
    };
    Simulator::with_scenario(params, trace, &PolicyRegistry::with_builtins())
        .expect("builtin construction")
        .run()
}

/// Requests meeting their own class SLO (the per-class goodput counter).
fn good_count(exp: &ExperimentConfig, report: &SimReport) -> usize {
    let spec = ScenarioRegistry::with_builtins()
        .build(exp.scenario_name.as_deref().unwrap(), exp)
        .unwrap();
    let slos = spec.slos();
    report
        .completed
        .iter()
        .filter(|r| r.meets_slo(slos.get(r.class)))
        .count()
}

#[test]
fn goodput_orders_oracle_llm_native_none_under_bursty_mixed() {
    // the sanity ordering the whole subsystem exists for: with
    // rescheduling on, better length information must not hurt. Summed
    // over seeds with a small slack (weak ordering — equality is fine
    // when the cluster is unsaturated).
    let (mut oracle, mut llm, mut none) = (0usize, 0usize, 0usize);
    for seed in [3u64, 17, 29] {
        let e = scenario_exp("bursty_mixed", "oracle", 0.0, seed);
        oracle += good_count(&e, &run(&e, 150));
        let e = scenario_exp("bursty_mixed", "llm_native", 0.5, seed);
        llm += good_count(&e, &run(&e, 150));
        let e = scenario_exp("bursty_mixed", "none", 0.0, seed);
        none += good_count(&e, &run(&e, 150));
    }
    assert!(oracle > 0 && llm > 0 && none > 0, "{oracle}/{llm}/{none}");
    assert!(
        oracle as f64 >= llm as f64 * 0.97,
        "oracle ({oracle}) should not lose to llm_native ({llm})"
    );
    assert!(
        llm as f64 >= none as f64 * 0.97,
        "llm_native ({llm}) should not lose to none ({none})"
    );
    assert!(
        oracle as f64 >= none as f64 * 0.99,
        "oracle ({oracle}) must at least match none ({none})"
    );
}

#[test]
fn scorecard_mae_matches_injected_noise() {
    // oracle: exact predictions, so the completion-time scorecard must be
    // exactly zero-error (and populated — the wiring claim)
    let e = scenario_exp("bursty_mixed", "oracle", 0.0, 7);
    let report = run(&e, 80);
    let t = report.scorecard.total();
    assert!(t.n > 0, "oracle runs must still log predictions");
    assert_eq!(t.mae(), 0.0, "oracle MAE must be exactly zero");
    assert_eq!(t.bias(), 0.0, "oracle bias must be exactly zero");

    // llm_native at rel_err 0.5: the measured relative MAE must recover
    // the injected noise scale. σ_eff shrinks from 0.5 (progress 0) to
    // 0.175 (late), and E|e^N(0,σ)−1| ≈ 0.14..0.41 over that range, so
    // the aggregate relative MAE lands well inside (0.06, 0.9).
    let e = scenario_exp("bursty_mixed", "llm_native", 0.5, 7);
    let report = run(&e, 80);
    let t = report.scorecard.total();
    assert!(t.n > 0);
    let rel = t.rel_mae();
    assert!(
        rel > 0.06 && rel < 0.9,
        "relative MAE {rel:.3} should reflect the injected rel_err 0.5"
    );
    // log-normal noise over-predicts on average (E[e^N] = e^{σ²/2} > 1)
    assert!(
        t.bias() > 0.0,
        "multiplicative log-normal noise must show positive bias, got {}",
        t.bias()
    );

    // `none` never logs anything
    let e = scenario_exp("bursty_mixed", "none", 0.0, 7);
    let report = run(&e, 40);
    assert!(report.scorecard.is_empty());
}

#[test]
fn debiased_scorecard_bias_is_smaller_than_llm_native() {
    // the debiased builtin learns from the same completion feedback the
    // scorecard accumulates; over a run its |bias| must come out below
    // the raw llm_native predictor's at the same noise level
    let e = scenario_exp("bursty_mixed", "llm_native", 0.5, 11);
    let raw = run(&e, 200).scorecard.total();
    let e = scenario_exp("bursty_mixed", "debiased", 0.5, 11);
    let deb = run(&e, 200).scorecard.total();
    assert!(raw.n > 0 && deb.n > 0);
    assert!(
        deb.bias().abs() < raw.bias().abs(),
        "debiasing must shrink the bias: raw {:+.1} vs debiased {:+.1}",
        raw.bias(),
        deb.bias()
    );
}

#[test]
fn same_seed_same_predictor_is_deterministic_in_scale_and_migration_traces() {
    // determinism satellite: same seed + same predictor ⇒ identical
    // scale-action trace AND identical migration trace (elastic pool +
    // noisy predictor + rescheduler all driven off the one seed)
    let mk = || {
        let mut e = scenario_exp("diurnal_chat", "llm_native", 0.5, 13);
        e.scaling_policy = "predictive".to_string();
        e.elastic.scale_interval_s = 2.0;
        e.elastic.cooldown_s = 2.0;
        e.elastic.flip_delay_s = 1.0;
        e.record_traces = true;
        e
    };
    let a = run(&mk(), 120);
    let b = run(&mk(), 120);
    assert_eq!(a.scale_actions, b.scale_actions, "scale-action traces differ");
    let migrations = |r: &SimReport| -> Vec<(f64, u64, usize, usize)> {
        r.recorder
            .rows()
            .iter()
            .filter_map(|row| match row.event {
                TraceEvent::Migration {
                    request, src, dst, ..
                } => Some((row.t, request, src, dst)),
                _ => None,
            })
            .collect()
    };
    assert_eq!(migrations(&a), migrations(&b), "migration traces differ");
    assert_eq!(a.completed.len(), b.completed.len());
    assert_eq!(a.duration.to_bits(), b.duration.to_bits());
}

#[test]
fn quantile_aggregates_agree_between_state_and_snapshot_views() {
    // predicted_work_q is the elastic::predictive planning signal: the
    // O(1) state aggregate and the snapshot recomputation must agree, and
    // p90 must sit above the mean exactly when estimates carry spread
    let mut st = ClusterState::new(2, 100_000, 1.0, 0.02, 1e-6);
    st.admit(0, 1, 1_000, Some(Prediction::new(500.0, 100.0, 0)));
    st.admit(0, 2, 2_000, Some(Prediction::new(300.0, 50.0, 0)));
    st.admit(1, 3, 500, Some(Prediction::exact(400.0)));
    let snap = st.snapshot();
    for q in [0.5, 0.9, 0.99] {
        for i in 0..2 {
            let a = st.view().instance(i).predicted_work_q(q);
            let b = snap.view().instance(i).predicted_work_q(q);
            assert!((a - b).abs() < 1e-9, "q={q} instance {i}: {a} vs {b}");
        }
    }
    let mean = st.view().instance(0).predicted_work();
    let p90 = st.view().instance(0).predicted_work_q(0.9);
    assert!((mean - 3_800.0).abs() < 1e-9);
    // z(0.9) ≈ 1.2816 over Σσ = 150
    assert!((p90 - (3_800.0 + 1.2815515655446004 * 150.0)).abs() < 1e-6);
    // zero-spread estimates: every quantile equals the mean
    let exact = st.view().instance(1).predicted_work_q(0.99);
    assert!((exact - st.view().instance(1).predicted_work()).abs() < 1e-12);
    // releases keep the sigma aggregate coherent (consistency_diff covers
    // the mean AND sigma sums)
    st.release(1);
    assert!(st.consistency_diff(&st.snapshot()).is_none());
}

#[test]
fn custom_predictor_registers_and_runs_end_to_end() {
    // the PredictorRegistry mirror of tests/policy_registry.rs: a
    // third-party predictor selected purely by string through
    // Simulator::with_registries
    struct Flat;
    impl LengthPredictor for Flat {
        fn predict(&mut self, input: &PredictInput) -> Option<Prediction> {
            Some(Prediction::new(64.0, 16.0, input.generated as u64))
        }
        fn name(&self) -> String {
            "flat64".into()
        }
    }
    let mut predictors = PredictorRegistry::with_builtins();
    predictors.register("flat64", |_| Ok(Box::new(Flat)));

    let mut exp = scenario_exp("bursty_mixed", "flat64", 0.0, 5);
    exp.cluster.n_decode = 3;
    let spec = ScenarioRegistry::with_builtins()
        .build("bursty_mixed", &exp)
        .unwrap();
    let trace = spec.generate(40, exp.cluster.seed);
    let params = SimParams {
        exp,
        validate_state: true,
        ..Default::default()
    };
    let report = Simulator::with_registries(
        params,
        trace,
        &PolicyRegistry::with_builtins(),
        &predictors,
    )
    .expect("custom predictor must build by name")
    .run();
    assert_eq!(report.completed.len() + report.n_failed, 40);
    assert!(
        report.scorecard.total().n > 0,
        "custom predictions flow into the scorecard too"
    );

    // an unregistered name surfaces the registry error, not a fallback
    let mut exp = scenario_exp("bursty_mixed", "not_registered", 0.0, 5);
    exp.cluster.n_decode = 3;
    let spec = ScenarioRegistry::with_builtins()
        .build("bursty_mixed", &exp)
        .unwrap();
    let trace = spec.generate(4, exp.cluster.seed);
    let err = Simulator::with_scenario(
        SimParams {
            exp,
            ..Default::default()
        },
        trace,
        &PolicyRegistry::with_builtins(),
    )
    .err()
    .expect("unknown predictor must fail construction")
    .to_string();
    assert!(err.contains("unknown predictor `not_registered`"), "{err}");
    assert!(err.contains("llm_native"), "{err}");
}
