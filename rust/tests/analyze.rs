//! `star analyze` acceptance tests: each rule R1–R7 fires on the fixture
//! corpus exactly where the fixtures promise (one negative test per rule,
//! so CI fails if a rule is silently disabled), and the real `rust/src`
//! tree is clean. Runs the library API directly; the process-level CLI
//! surface (exit codes, output format, unknown-rule errors) is covered in
//! `tests/cli_errors.rs`.

use std::path::{Path, PathBuf};

use star::analyze::{analyze_tree, resolve_rules, Finding, RULES};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/analyze")
}

fn src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

fn run(rules: &[&str]) -> Vec<Finding> {
    analyze_tree(&fixture_root(), rules).expect("fixture corpus analyzes")
}

/// (relative file, line) pairs of the findings, for exact-location pins.
fn locations(findings: &[Finding]) -> Vec<(String, u32)> {
    findings
        .iter()
        .map(|f| {
            let rel = f
                .file
                .split("fixtures/analyze/")
                .nth(1)
                .unwrap_or(&f.file)
                .to_string();
            (rel, f.line)
        })
        .collect()
}

#[test]
fn r1_fires_on_hash_collections_but_not_tests_or_waivers() {
    let findings = run(&["R1"]);
    assert_eq!(
        locations(&findings),
        vec![
            ("sim/engine.rs".to_string(), 8),
            ("sim/engine.rs".to_string(), 13),
        ],
        "{findings:#?}"
    );
    // the fixture's #[cfg(test)] HashMap and the ANALYZE-OK'd HashSet in
    // coordinator/state.rs must both be absent from the list above
    assert!(findings.iter().all(|f| f.rule == "R1"));
}

#[test]
fn r2_fires_on_wall_clock_in_the_simulated_core() {
    let findings = run(&["R2"]);
    assert_eq!(
        locations(&findings),
        vec![
            ("coordinator/state.rs".to_string(), 7),
            ("coordinator/state.rs".to_string(), 10),
            ("coordinator/state.rs".to_string(), 16),
        ],
        "{findings:#?}"
    );
    // serve/clean.rs calls Instant::now() and must be exempt (live layer)
    assert!(locations(&findings).iter().all(|(f, _)| !f.starts_with("serve/")));
}

#[test]
fn r3_fires_outside_allowlist_and_on_missing_safety_comment() {
    let findings = run(&["R3"]);
    assert_eq!(
        locations(&findings),
        vec![
            ("kvcache/unsafe_bad.rs".to_string(), 5),
            ("runtime/models.rs".to_string(), 6),
        ],
        "{findings:#?}"
    );
    assert!(findings[0].message.contains("outside the allowlist"));
    assert!(findings[1].message.contains("SAFETY"));
}

#[test]
fn r4_fires_on_bare_unwrap_outside_tests() {
    let findings = run(&["R4"]);
    assert_eq!(
        locations(&findings),
        vec![("sim/engine.rs".to_string(), 14)],
        "{findings:#?}"
    );
}

#[test]
fn r5_fires_on_unmatched_and_unlisted_event_variants() {
    let findings = run(&["R5"]);
    assert_eq!(
        locations(&findings),
        vec![
            ("sim/engine.rs".to_string(), 11),
            ("sim/events.rs".to_string(), 8),
        ],
        "{findings:#?}"
    );
    assert!(findings.iter().all(|f| f.message.contains("Finish")));
}

#[test]
fn r6_fires_on_the_unhandled_trace_event_variant() {
    let findings = run(&["R6"]);
    assert_eq!(
        locations(&findings),
        vec![("metrics/recorder.rs".to_string(), 7)],
        "{findings:#?}"
    );
    assert!(findings[0].message.contains("KvSample"), "{findings:#?}");
    assert!(
        findings[0].message.contains("span assembler"),
        "{findings:#?}"
    );
}

#[test]
fn r7_fires_on_shared_mutable_globals_but_not_tests_or_waivers() {
    let findings = run(&["R7"]);
    assert_eq!(
        locations(&findings),
        vec![
            ("sim/globals.rs".to_string(), 8),
            ("sim/globals.rs".to_string(), 10),
            ("sim/globals.rs".to_string(), 12),
        ],
        "{findings:#?}"
    );
    // the ANALYZE-OK'd Mutex static (line 15) and the #[cfg(test)]
    // static mut (line 19) must both be absent from the list above
    assert!(findings.iter().all(|f| f.rule == "R7"));
    assert!(findings[0].message.contains("static mut"), "{findings:#?}");
    assert!(findings[1].message.contains("OnceLock"), "{findings:#?}");
    assert!(findings[2].message.contains("Atomic"), "{findings:#?}");
}

#[test]
fn every_cataloged_rule_fires_on_the_fixture_corpus() {
    // belt-and-braces for the per-rule pins above: a rule that exists in
    // the catalog but produces nothing on the known-bad corpus has been
    // silently disabled
    for rule in RULES {
        let findings = run(&[rule.id]);
        assert!(
            !findings.is_empty(),
            "rule {} ({}) produced no findings on the fixture corpus",
            rule.id,
            rule.name
        );
        assert!(findings.iter().all(|f| f.rule == rule.id));
    }
}

#[test]
fn the_real_tree_is_clean() {
    let all: Vec<&str> = RULES.iter().map(|r| r.id).collect();
    let findings = analyze_tree(&src_root(), &all).expect("src analyzes");
    let rendered: Vec<String> = findings.iter().map(Finding::render).collect();
    assert!(
        findings.is_empty(),
        "rust/src must be analyze-clean:\n{}",
        rendered.join("\n")
    );
}

#[test]
fn findings_are_deterministically_ordered() {
    let all: Vec<&str> = RULES.iter().map(|r| r.id).collect();
    let a: Vec<String> = analyze_tree(&fixture_root(), &all)
        .unwrap()
        .iter()
        .map(Finding::render)
        .collect();
    let b: Vec<String> = analyze_tree(&fixture_root(), &all)
        .unwrap()
        .iter()
        .map(Finding::render)
        .collect();
    assert_eq!(a, b);
    let mut sorted = a.clone();
    sorted.sort();
    assert_eq!(a, sorted, "report must be sorted by (file, line, rule)");
}

#[test]
fn rule_selection_validates_names() {
    assert_eq!(resolve_rules(Some("r2")).unwrap(), vec!["R2"]);
    assert_eq!(resolve_rules(Some("R7")).unwrap(), vec!["R7"]);
    let err = resolve_rules(Some("R9")).unwrap_err().to_string();
    for id in ["R1", "R2", "R3", "R4", "R5", "R6", "R7"] {
        assert!(err.contains(id), "candidate list must name {id}: {err}");
    }
}

#[test]
fn validated_events_const_covers_every_variant() {
    // the runtime half of R5: the engine asserts membership under
    // validate_state, so the const must name all ten variants
    use star::sim::VALIDATED_EVENTS;
    for v in [
        "Arrival",
        "PrefillDone",
        "DecodeStep",
        "MigrationDone",
        "SchedulerTick",
        "SessionFollowUp",
        "ScaleTick",
        "InstanceReady",
        "DrainComplete",
        "PrefixTransferDone",
    ] {
        assert!(VALIDATED_EVENTS.contains(&v), "missing {v}");
    }
}
