//! Observability subsystem integration tests (ISSUE 9): `[obs] enabled =
//! false` must be bit-for-bit inert, obs-ON runs must be *passive* (the
//! scheduling trajectory is identical to baseline) and same-seed
//! deterministic, the flight-recorder sampling/ring bounds must hold
//! end-to-end, the SLO-violation join (spans × decision log) must cover
//! every violating request, and the `star trace` CLI must export
//! byte-identical Chrome-trace / JSONL payloads across same-seed runs.

use std::process::Command;

use star::bench::json::{parse, Json};
use star::bench::scenarios::ScenarioRegistry;
use star::config::ExperimentConfig;
use star::coordinator::PolicyRegistry;
use star::metrics::Slo;
use star::obs::DecisionKind;
use star::sim::{SimParams, SimReport, Simulator};

fn base_exp(seed: u64) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.cluster.n_decode = 3;
    exp.cluster.n_prefill = 2;
    exp.cluster.rps = 0.5;
    exp.cluster.seed = seed;
    exp.cluster.kv_capacity_tokens = 400_000; // roomy: nothing fails
    exp.predictor = "oracle".to_string();
    exp.scenario_name = Some("bursty_mixed".to_string());
    exp.record_traces = true;
    exp
}

fn run(exp: ExperimentConfig, n: usize) -> SimReport {
    let spec = ScenarioRegistry::with_builtins()
        .build(exp.scenario_name.as_deref().unwrap(), &exp)
        .expect("builtin scenario");
    let trace = spec.generate(n, exp.cluster.seed);
    let params = SimParams {
        exp,
        ..Default::default()
    };
    Simulator::with_scenario(params, trace, &PolicyRegistry::with_builtins())
        .expect("builtin policies")
        .run()
}

/// Every recorded trace row, rendered exactly — the bit-for-bit currency
/// of the differential tests.
fn trace_rows(r: &SimReport) -> Vec<String> {
    r.recorder
        .rows()
        .iter()
        .map(|row| format!("{:.12}|{:?}", row.t, row.event))
        .collect()
}

/// Per-request completion fingerprint (sorted by id).
fn completion_rows(r: &SimReport) -> Vec<String> {
    let mut rows: Vec<String> = r
        .completed
        .iter()
        .map(|l| {
            format!(
                "{}|{:?}|{:?}|{}|{}",
                l.id, l.first_token, l.finished, l.output_tokens, l.prompt_tokens
            )
        })
        .collect();
    rows.sort();
    rows
}

#[test]
fn obs_off_is_bit_for_bit_inert() {
    // baseline: the defaults (obs off) — then obs still off but with every
    // [obs] knob set to an odd value. Both must produce identical traces,
    // and the report's obs section must be the inert default.
    let base = run(base_exp(42), 60);
    assert!(!base.obs.enabled);
    assert!(base.obs.spans.is_empty());
    assert_eq!(base.obs.spans.seen, 0);
    assert_eq!(base.obs.registry.counter("requests.arrived"), 0);
    assert!(base.obs.registry.series().is_empty());
    assert!(base.obs.decisions.is_empty());
    assert!(base.obs.summary().contains("disabled"), "{}", base.obs.summary());

    let mut odd = base_exp(42);
    odd.obs.enabled = false;
    odd.obs.sample_every_s = 0.25;
    odd.obs.ring_capacity = 7;
    odd.obs.sample_rate = 0.5;
    let b = run(odd, 60);
    assert_eq!(
        trace_rows(&base),
        trace_rows(&b),
        "[obs] enabled = false must be bit-for-bit identical to baseline"
    );
    assert_eq!(completion_rows(&base), completion_rows(&b));
    assert!((base.duration - b.duration).abs() < 1e-12);
    assert_eq!(base.migrations, b.migrations);
    assert_eq!(base.oom_events, b.oom_events);
    assert!(!b.obs.enabled);
}

#[test]
fn obs_on_is_passive_and_same_seed_deterministic() {
    let base = run(base_exp(42), 60);
    let mk = || {
        let mut exp = base_exp(42);
        exp.obs.enabled = true;
        run(exp, 60)
    };
    let a = mk();
    // passivity: observability reads the run, it never steers it — the
    // trajectory with obs ON equals the baseline with obs OFF
    assert_eq!(
        trace_rows(&base),
        trace_rows(&a),
        "obs must be passive: enabling it cannot change the trajectory"
    );
    assert_eq!(completion_rows(&base), completion_rows(&a));

    // determinism: two obs-on runs agree on every observable
    let b = mk();
    assert_eq!(a.obs.summary(), b.obs.summary());
    assert_eq!(a.obs.spans.len(), b.obs.spans.len());
    assert_eq!(a.obs.decisions.len(), b.obs.decisions.len());
    let counters = |r: &SimReport| -> Vec<(String, u64)> {
        r.obs
            .registry
            .counters()
            .map(|(k, v)| (k.to_string(), v))
            .collect()
    };
    assert_eq!(counters(&a), counters(&b));

    // and the content is real
    assert!(a.obs.enabled);
    assert!(a.obs.spans.seen > 0);
    assert!(a.obs.registry.counter("requests.arrived") > 0);
    assert_eq!(
        a.obs.registry.counter("requests.finished"),
        a.completed.len() as u64,
        "the finished counter is the completion count"
    );
    let ttft = a.obs.registry.histogram("ttft_s").expect("ttft histogram");
    assert_eq!(ttft.count as usize, a.completed.len());
    let series = a.obs.registry.series();
    assert!(!series.is_empty(), "per-tick series must be sampled");
    assert!(
        series.windows(2).all(|w| w[0].t <= w[1].t),
        "series timestamps are nondecreasing"
    );
    assert!(!a.obs.decisions.is_empty());
    assert!(
        a.obs.decisions.records().iter().all(|d| d.cost_us == 0),
        "sim decisions carry the deterministic work proxy, never wall time"
    );
    assert!(a
        .obs
        .decisions
        .records()
        .iter()
        .any(|d| d.kind == DecisionKind::Dispatch && d.request.is_some() && d.chosen.is_some()));
    // rate 1.0 + roomy ring: the first completed request has a span
    let first = a.completed.first().expect("requests completed");
    let span = a.obs.spans.span_of(first.id).expect("span retained");
    assert!(span.finished.is_some(), "completed request's span finished");
}

#[test]
fn sampling_rate_and_ring_capacity_bound_the_flight_recorder() {
    let mk = |rate: f64, cap: usize| {
        let mut exp = base_exp(7);
        exp.obs.enabled = true;
        exp.obs.sample_rate = rate;
        exp.obs.ring_capacity = cap;
        // spans must assemble even with plain trace recording off (the
        // obs switch force-enables the recorder, passively)
        exp.record_traces = false;
        run(exp, 60)
    };
    let none = mk(0.0, 4096);
    assert_eq!(none.obs.spans.len(), 0, "rate 0.0 retains nothing");
    assert_eq!(none.obs.spans.sampled, 0);
    assert!(none.obs.spans.seen > 0, "seen still counts every arrival");

    let all = mk(1.0, 4096);
    assert_eq!(all.obs.spans.sampled, all.obs.spans.seen, "rate 1.0 keeps all");
    assert_eq!(all.obs.spans.dropped, 0);
    assert_eq!(all.obs.spans.len() as u64, all.obs.spans.sampled);

    let ringed = mk(1.0, 5);
    assert_eq!(ringed.obs.spans.len(), 5, "ring bound holds");
    assert!(ringed.obs.spans.dropped > 0, "evictions are counted");
    assert_eq!(
        ringed.obs.spans.sampled, all.obs.spans.sampled,
        "sampling is independent of the ring bound"
    );

    let half = mk(0.5, 4096);
    assert!(half.obs.spans.sampled > 0, "{:?}", half.obs.spans.sampled);
    assert!(
        half.obs.spans.sampled < half.obs.spans.seen,
        "rate 0.5 keeps some, drops some ({} of {})",
        half.obs.spans.sampled,
        half.obs.spans.seen
    );
    // head-based sampling off the run seed: same seed, same retained set
    let half2 = mk(0.5, 4096);
    let ids = |r: &SimReport| -> Vec<u64> {
        r.obs.spans.spans().iter().map(|s| s.request).collect()
    };
    assert_eq!(ids(&half), ids(&half2));
}

#[test]
fn slo_violation_join_covers_every_violating_request() {
    // overload the cluster (one prefill instance, 3 rps bursty traffic) so
    // queueing pushes TTFT past the 1 s default SLO for a healthy fraction
    // of requests — the population `star trace slo-violations` lists
    let mut exp = base_exp(11);
    exp.obs.enabled = true;
    exp.cluster.rps = 3.0;
    exp.cluster.n_prefill = 1;
    let r = run(exp, 80);
    let slo = Slo::default();
    let violating: Vec<_> = r.completed.iter().filter(|l| !l.meets_slo(slo)).collect();
    assert!(
        !violating.is_empty(),
        "overloaded bursty run must produce SLO violations"
    );
    for l in &violating {
        let span = r
            .obs
            .spans
            .span_of(l.id)
            .expect("rate-1.0 sampling retains every violating request");
        assert!(
            (span.arrived - l.arrival).abs() < 1e-9,
            "span and latency record agree on arrival"
        );
        let tl = span.timeline();
        assert!(tl.contains("arrived"), "{tl}");
        let decisions = r.obs.decisions.for_request(l.id);
        assert!(
            decisions.iter().any(|d| d.kind == DecisionKind::Dispatch),
            "request {} has no dispatch decision in the attribution log",
            l.id
        );
        assert!(
            decisions.iter().all(|d| d.request == Some(l.id)),
            "for_request must only return the request's own decisions"
        );
    }
}

// ---------------------------------------------------------------- CLI --

fn star() -> Command {
    Command::new(env!("CARGO_BIN_EXE_star"))
}

fn run_cli(args: &[&str]) -> (bool, Vec<u8>, String) {
    let out = star().args(args).output().expect("spawn star binary");
    (
        out.status.success(),
        out.stdout,
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

const TRACE_ARGS: &[&str] = &[
    "--scenario",
    "bursty_mixed",
    "--requests",
    "40",
    "--rps",
    "0.5",
    "--kv-capacity",
    "400000",
    "--seed",
    "13",
];

#[test]
fn trace_export_chrome_is_byte_identical_and_valid_json() {
    let mut args = vec!["trace", "export", "--format", "chrome"];
    args.extend_from_slice(TRACE_ARGS);
    let (ok, out_a, err) = run_cli(&args);
    assert!(ok, "star trace export --format chrome failed: {err}");
    let (ok, out_b, err) = run_cli(&args);
    assert!(ok, "{err}");
    assert_eq!(
        out_a, out_b,
        "same seed must export byte-identical chrome JSON"
    );
    let text = String::from_utf8(out_a).expect("utf8 payload");
    let v = parse(&text).expect("chrome export must be valid JSON");
    assert_eq!(v.get("displayTimeUnit"), Some(&Json::Str("ms".to_string())));
    let Some(Json::Arr(evs)) = v.get("traceEvents") else {
        panic!("traceEvents must be an array");
    };
    assert!(evs.len() > 10, "expected a populated trace: {}", evs.len());
    // duration slices (request lifecycles), counter samples (metrics),
    // and instants (decisions) are all present
    for ph in ["X", "C", "i"] {
        assert!(
            evs.iter()
                .any(|e| e.get("ph") == Some(&Json::Str(ph.to_string()))),
            "no `{ph}` events in the export"
        );
    }
}

#[test]
fn trace_export_jsonl_is_byte_identical_and_line_parseable() {
    let mut args = vec!["trace", "export", "--format", "jsonl"];
    args.extend_from_slice(TRACE_ARGS);
    let (ok, out_a, err) = run_cli(&args);
    assert!(ok, "star trace export --format jsonl failed: {err}");
    let (ok, out_b, err) = run_cli(&args);
    assert!(ok, "{err}");
    assert_eq!(out_a, out_b, "same seed must export byte-identical JSONL");
    let text = String::from_utf8(out_a).expect("utf8 payload");
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() > 10, "expected a populated dump: {}", lines.len());
    for line in &lines {
        parse(line).expect("every jsonl line parses");
    }
    assert!(lines[0].contains("\"type\":\"obs\""), "{}", lines[0]);
    for needle in ["\"type\":\"span\"", "\"type\":\"decision\"", "\"type\":\"series\""] {
        assert!(text.contains(needle), "missing {needle}");
    }
}

#[test]
fn trace_summarize_and_slo_violations_run_end_to_end() {
    let mut args = vec!["trace", "summarize"];
    args.extend_from_slice(TRACE_ARGS);
    let (ok, out, err) = run_cli(&args);
    assert!(ok, "star trace summarize failed: {err}");
    let out = String::from_utf8_lossy(&out);
    assert!(out.contains("obs:"), "{out}");
    assert!(out.contains("counter"), "{out}");
    assert!(out.contains("decisions"), "{out}");

    // overloaded run (one prefill instance, 3 rps): violations exist, and
    // each sampled one prints its span timeline plus its decisions
    let (ok, out, err) = run_cli(&[
        "trace",
        "slo-violations",
        "--scenario",
        "bursty_mixed",
        "--requests",
        "60",
        "--rps",
        "3.0",
        "--prefill",
        "1",
        "--decode",
        "3",
        "--kv-capacity",
        "400000",
        "--seed",
        "11",
    ]);
    assert!(ok, "slo-violations must exit 0: {err}");
    let out = String::from_utf8_lossy(&out);
    assert!(out.contains("slo-violations:"), "{out}");
    let n: usize = out
        .split("slo-violations: ")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .and_then(|s| s.parse().ok())
        .expect("header violation count");
    assert!(n > 0, "overloaded run must report violations: {out}");
    assert!(out.contains("spans:"), "violating request timeline: {out}");
    assert!(out.contains("decision t="), "attributed decisions: {out}");
}
