//! Offline stub of the `xla` crate (xla-rs PJRT bindings), vendored so the
//! STAR crate builds and tests without network access or an XLA toolchain.
//!
//! Host-side `Literal` operations (construction, reshape, readback) are
//! implemented for real — they are plain data shuffling. Everything that
//! needs the PJRT C API (`PjRtClient::cpu`, compilation, execution) returns
//! a descriptive [`Error`], so `StarRuntime::load` fails cleanly and every
//! artifact-dependent test/bench skips, exactly as when `make artifacts`
//! has not run. Swap this path dependency for the real `xla` crate to run
//! the live serving stack.

use std::fmt;

/// Stub error: either a host-side shape/dtype misuse or "no backend".
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn no_backend<T>() -> Result<T> {
    Err(Error(
        "PJRT backend unavailable: built against the offline xla stub \
         (vendor/xla); link the real xla crate to execute artifacts"
            .to_string(),
    ))
}

/// Element types the stub can store and read back.
#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host tensor literal: shape + flat storage (or a tuple of literals).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Conversion trait tying Rust element types to [`Data`] variants.
pub trait NativeType: Sized + Clone {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Result<Vec<Self>> {
        match d {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".to_string())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Result<Vec<Self>> {
        match d {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".to_string())),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: T::wrap(v.to_vec()),
        }
    }

    /// Reshape without moving data; element counts must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: {have} elements vs {want}",
                self.dims
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            Data::Tuple(_) => Err(Error("tuple literal has no array shape".to_string())),
            _ => Ok(ArrayShape {
                dims: self.dims.clone(),
            }),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(xs) => Ok(xs),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        let mut xs = self.to_tuple()?;
        if xs.len() != 1 {
            return Err(Error(format!("expected 1-tuple, got {}", xs.len())));
        }
        Ok(xs.remove(0))
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        let mut xs = self.to_tuple()?;
        if xs.len() != 3 {
            return Err(Error(format!("expected 3-tuple, got {}", xs.len())));
        }
        let c = xs.remove(2);
        let b = xs.remove(1);
        let a = xs.remove(0);
        Ok((a, b, c))
    }
}

/// Array shape (dims only; the stub carries no layout/dtype metadata).
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (opaque in the stub; parsing needs the XLA runtime).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        no_backend()
    }
}

/// Computation handle (never constructible without a real proto).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. `cpu()` always fails in the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        no_backend()
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        no_backend()
    }
}

/// Compiled executable handle (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        no_backend()
    }
}

/// Device buffer handle (never constructible in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        no_backend()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn backend_paths_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
